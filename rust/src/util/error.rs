//! Minimal error substrate (anyhow substitute for the offline crate set).
//!
//! Provides a string-backed [`Error`], a `Result` alias, the
//! [`Context`]/`with_context` extension trait, and `anyhow!` / `bail!`
//! macros with the same call syntax the `runtime` layer was written
//! against. Context is prepended `"<context>: <cause>"`, matching the
//! chain formatting `anyhow` renders with `{:#}`.

use std::fmt;

/// A string-backed error with prepended context.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (anyhow's whole-chain form) and `{}` both print the full
        // message; context is already folded into the string.
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (or `None`s), like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any `Display` value
/// (anyhow! substitute).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Early-return with an [`Error`] (bail! substitute).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::anyhow!("inner {}", 42))
    }

    #[test]
    fn context_prepends() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn with_context_is_lazy_and_option_works() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                crate::bail!("flagged");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged");
    }
}
