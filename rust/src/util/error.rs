//! Minimal error substrate (anyhow substitute for the offline crate set).
//!
//! Provides a string-backed [`Error`], a `Result` alias, the
//! [`Context`]/`with_context` extension trait, and `anyhow!` / `bail!`
//! macros with the same call syntax the `runtime` layer was written
//! against. Context is prepended `"<context>: <cause>"`, matching the
//! chain formatting `anyhow` renders with `{:#}`.

use std::fmt;

/// Typed failure modes of the transform-service request lifecycle
/// (`coordinator`): validation, deadline, admission control, execution,
/// and shutdown failures, carried through the reply channels end to end
/// and rendered at the API edge via `Display`.
///
/// Unlike the string-backed [`Error`] below (an `anyhow` substitute for
/// the offline `runtime` layer), this enum is *matchable*: clients
/// distinguish a shed request (retry later, honoring
/// [`TransformError::Overloaded`]'s `retry_after` hint) from a
/// malformed one (never retry) from an execution failure (already
/// retried once on the degraded serial plan by the service itself).
#[derive(Debug, Clone, PartialEq)]
pub enum TransformError {
    /// The request failed validation (rank/shape/payload mismatch);
    /// retrying the identical request can never succeed.
    InvalidRequest(String),
    /// The request's deadline passed before a worker started executing
    /// it; it was dropped without consuming pool work.
    DeadlineExceeded,
    /// Admission control shed the request: accepting it would push the
    /// service's in-flight payload past its budget. `retry_after` is the
    /// suggested client backoff.
    Overloaded {
        /// Suggested backoff before resubmitting.
        retry_after: std::time::Duration,
    },
    /// The executing plan panicked (and, where applicable, the one-shot
    /// degraded-serial retry also failed).
    ExecutionPanicked(String),
    /// The backend reported an execution error (PJRT failure or an
    /// injected fault) and the degraded retry also failed.
    ExecutionFailed(String),
    /// The service is shutting down and no longer accepts or answers
    /// requests.
    ShuttingDown,
}

impl fmt::Display for TransformError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransformError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            TransformError::DeadlineExceeded => f.write_str("deadline exceeded"),
            TransformError::Overloaded { retry_after } => {
                write!(f, "overloaded, retry after {retry_after:?}")
            }
            TransformError::ExecutionPanicked(m) => write!(f, "execution panicked: {m}"),
            TransformError::ExecutionFailed(m) => write!(f, "execution failed: {m}"),
            TransformError::ShuttingDown => f.write_str("service shutting down"),
        }
    }
}

impl std::error::Error for TransformError {}

impl TransformError {
    /// Whether resubmitting the same request later can succeed
    /// (load/timing failures, not validation failures).
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            TransformError::DeadlineExceeded | TransformError::Overloaded { .. }
        )
    }
}

/// A string-backed error with prepended context.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (anyhow's whole-chain form) and `{}` both print the full
        // message; context is already folded into the string.
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (or `None`s), like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily-built context message (skipped on success).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any `Display` value
/// (anyhow! substitute).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Early-return with an [`Error`] (bail! substitute).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::anyhow!("inner {}", 42))
    }

    #[test]
    fn context_prepends() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn with_context_is_lazy_and_option_works() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn transform_error_renders_and_classifies() {
        use std::time::Duration;
        let shed = TransformError::Overloaded { retry_after: Duration::from_millis(5) };
        assert!(shed.is_retryable());
        assert!(shed.to_string().starts_with("overloaded"));
        assert!(TransformError::DeadlineExceeded.is_retryable());
        let bad = TransformError::InvalidRequest("rank".into());
        assert!(!bad.is_retryable());
        assert_eq!(bad.to_string(), "invalid request: rank");
        // the worker-panic path greps for this word in tests
        assert!(TransformError::ExecutionPanicked("boom".into())
            .to_string()
            .contains("panicked"));
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                crate::bail!("flagged");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged");
    }
}
