//! Summary statistics shared by the bench harness and the service metrics.

/// Summary of a sample of f64 observations (times in seconds, sizes, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator).
    pub std: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// 50th percentile (linear-interpolated).
    pub median: f64,
    /// 95th percentile (linear-interpolated).
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    ///
    /// ```
    /// use mddct::util::stats::Summary;
    ///
    /// let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
    /// assert_eq!(s.n, 4);
    /// assert_eq!(s.mean, 2.5);
    /// assert_eq!(s.median, 2.5);
    /// assert_eq!((s.min, s.max), (1.0, 4.0));
    /// ```
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Relative standard deviation (the paper reports std < 1% of mean).
    pub fn rel_std(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Streaming histogram with fixed log-spaced latency buckets (metrics).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket upper bounds in seconds
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Number of recorded observations.
    pub total: u64,
    /// Sum of all recorded values in seconds (for the mean).
    pub sum: f64,
    /// Largest recorded value in seconds.
    pub max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1us .. 10s, one bucket per decade third
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b <= 10.0 {
            bounds.push(b);
            bounds.push(b * 2.0);
            bounds.push(b * 5.0);
            b *= 10.0;
        }
        let n = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; n + 1], total: 0, sum: 0.0, max: 0.0 }
    }
}

impl LatencyHistogram {
    /// Record one observation (seconds) into its log-spaced bucket.
    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
    }

    /// Mean of all recorded values; 0 when nothing was recorded.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.total, 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(1.0));
        assert!((h.mean() - 0.005005).abs() < 1e-6);
    }
}
