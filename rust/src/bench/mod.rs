//! Benchmark substrate (criterion substitute) + the analytic models
//! behind Tables III and VI. The per-table bench binaries live in
//! `rust/benches/` and print the same rows/series the paper reports.

pub mod harness;
pub mod intensity;
pub mod roofline;
pub mod table;

pub use harness::{black_box, time_fn, BenchConfig};
pub use table::{ms, ratio, us, Table};
