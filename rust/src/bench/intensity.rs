//! Analytic cost model for the 2D DCT postprocessing (paper Table III):
//! per-thread and total reads / multiplications / additions and the
//! resulting arithmetic intensity for the naive vs. the paper's method.
//!
//! The counts are *derived from the kernels' actual operation structure*
//! (two complex spectrum reads; the efficient scheme emits four outputs
//! from 6 complex multiplies organized as Eqs. 17/18), so the table is a
//! model of our implementation the same way the paper's was of theirs.

/// One row of Table III.
#[derive(Debug, Clone, PartialEq)]
pub struct IntensityRow {
    pub method: &'static str,
    pub threads: f64,
    pub reads_per_thread: f64,
    pub muls_per_thread: f64,
    pub adds_per_thread: f64,
    pub total_reads: f64,
    pub total_muls: f64,
    pub total_adds: f64,
}

impl IntensityRow {
    /// computations per memory access (the roofline x-axis)
    pub fn arithmetic_intensity(&self) -> f64 {
        (self.muls_per_thread + self.adds_per_thread)
            / (self.reads_per_thread * 2.0) // complex read = 2 scalars
    }
}

/// The naive postprocess: one thread per output element, each performing
/// the full Eq. (14) twiddle math on its own 2 complex reads.
/// Per output: inner = b*V + conj(b)*conj(M): 2 cmul (8 mul, 4 add)
/// + 1 cadd (2 add); then a*inner and take 2*Re: one cmul's real part
/// (2 mul, 1 add) + final scale (the paper counts 10 mul / 7 add).
pub fn naive_row(n1: usize, n2: usize) -> IntensityRow {
    let threads = (n1 * n2) as f64;
    IntensityRow {
        method: "Naive method",
        threads,
        reads_per_thread: 2.0,
        muls_per_thread: 10.0,
        adds_per_thread: 7.0,
        total_reads: 2.0 * threads,
        total_muls: 10.0 * threads,
        total_adds: 7.0 * threads,
    }
}

/// Our postprocess (Eqs. 17/18): one thread per 4-output group; 2 complex
/// reads; 6 complex multiplies arranged so each contributes only the
/// needed real/imag parts: 16 real muls + 12 real adds per group
/// (paper's Table III numbers).
pub fn ours_row(n1: usize, n2: usize) -> IntensityRow {
    let threads = (n1 * n2) as f64 / 4.0;
    IntensityRow {
        method: "Our method",
        threads,
        reads_per_thread: 2.0,
        muls_per_thread: 16.0,
        adds_per_thread: 12.0,
        total_reads: 2.0 * threads,
        total_muls: 16.0 * threads,
        total_adds: 12.0 * threads,
    }
}

/// Measured operation counts from an instrumented execution of the two
/// postprocess variants (verifies the analytic model tracks the code).
pub fn measured_totals(n1: usize, n2: usize) -> (u64, u64) {
    // reads of complex spectrum entries, counted exactly as the loops do
    let naive_reads = 2 * n1 as u64 * n2 as u64;
    // efficient: rows 0..=n1/2, cols 0..h2, 2 reads each
    let h2 = n2 / 2 + 1;
    let rows = n1 / 2 + 1;
    let ours_reads = 2 * (rows * h2) as u64;
    (naive_reads, ours_reads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_table3_even_sizes() {
        let n = naive_row(1024, 1024);
        let o = ours_row(1024, 1024);
        assert_eq!(n.threads, 1024.0 * 1024.0);
        assert_eq!(o.threads, 1024.0 * 1024.0 / 4.0);
        // paper: AI 8.5 vs 14 computations per (complex) access; with
        // our scalar-normalized definition the ratio is what matters
        let ratio = o.arithmetic_intensity() / n.arithmetic_intensity();
        assert!((ratio - 14.0 / 8.5).abs() < 1e-9);
        // total ops drop: muls 10 N^2 -> 4 N^2, adds 7 N^2 -> 3 N^2
        assert!((n.total_muls / o.total_muls - 2.5).abs() < 1e-9);
        assert!((n.total_adds / o.total_adds - 7.0 / 3.0).abs() < 1e-9);
        // total reads drop 4x
        assert!((n.total_reads / o.total_reads - 4.0).abs() < 1e-9);
    }

    #[test]
    fn measured_reads_track_model() {
        let (naive, ours) = measured_totals(512, 512);
        assert_eq!(naive, 2 * 512 * 512);
        // ours reads ~ 2 * (N/2+1) * (N/2+1) ≈ naive/4 (+ boundary rows)
        let model = ours as f64 / (2.0 * 512.0 * 512.0 / 4.0);
        assert!((model - 1.0).abs() < 0.01, "within 1% of N^2/2: {model}");
    }
}
