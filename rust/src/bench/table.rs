//! ASCII table formatting for the paper-table benches.

/// Simple column-aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("| {:width$} ", cells[i], width = widths[i]));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds as milliseconds with sensible precision.
pub fn ms(seconds: f64) -> String {
    let v = seconds * 1e3;
    if v >= 100.0 {
        format!("{v:.1}")
    } else if v >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

/// Format seconds as microseconds.
pub fn us(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e6)
}

/// Format a ratio like the paper's "(2.10)" columns.
pub fn ratio(this: f64, base: f64) -> String {
    format!("({:.2})", this / base)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["N", "time"]);
        t.row(&["512".into(), "0.12".into()]);
        t.row(&["16384".into(), "25.78".into()]);
        let s = t.render();
        assert!(s.contains("| N     | time  |"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.02578), "25.78");
        assert_eq!(us(0.00010162), "101.62");
        assert_eq!(ratio(2.0, 1.0), "(2.00)");
    }
}
