//! Roofline substitute for Table VI's GPU utilization counters.
//!
//! nvprof occupancy has no CPU analogue; the quantity Table VI actually
//! argues about is "the kernels are memory-bound and close to peak
//! bandwidth". We therefore (1) measure the machine's practical memory
//! bandwidth with a STREAM-like triad, (2) model the bytes each kernel
//! stage must move, and (3) report achieved/peak bandwidth fractions.

use std::time::Instant;

/// Measured machine characteristics.
#[derive(Debug, Clone, Copy)]
pub struct MachineRoofline {
    /// practical single-thread copy bandwidth, bytes/s
    pub copy_bw: f64,
    /// practical single-thread triad (a = b + s*c) bandwidth, bytes/s
    pub triad_bw: f64,
}

/// STREAM-like bandwidth measurement (single thread — the native
/// backend's transforms are single-threaded per request).
pub fn measure_machine(len: usize, reps: usize) -> MachineRoofline {
    let mut a = vec![1.0f64; len];
    let b = vec![2.0f64; len];
    let c = vec![3.0f64; len];
    // copy: 2 * 8 bytes per element per pass
    let t0 = Instant::now();
    for _ in 0..reps {
        a.copy_from_slice(&b);
        std::hint::black_box(&a);
    }
    let copy_bw = (2 * 8 * len * reps) as f64 / t0.elapsed().as_secs_f64();
    // triad: 3 * 8 bytes per element per pass
    let t1 = Instant::now();
    for _ in 0..reps {
        for i in 0..len {
            a[i] = b[i] + 0.5 * c[i];
        }
        std::hint::black_box(&a);
    }
    let triad_bw = (3 * 8 * len * reps) as f64 / t1.elapsed().as_secs_f64();
    MachineRoofline { copy_bw, triad_bw }
}

/// Bytes a kernel stage must move (f64 elements).
#[derive(Debug, Clone, Copy)]
pub struct StageTraffic {
    pub reads: usize,
    pub writes: usize,
}

impl StageTraffic {
    pub fn bytes(&self) -> f64 {
        ((self.reads + self.writes) * 8) as f64
    }
}

/// Traffic model of the 2D DCT preprocess: N^2 reads + N^2 writes
/// (each element touched exactly once — the paper's §III-A invariant).
pub fn preprocess_traffic(n1: usize, n2: usize) -> StageTraffic {
    StageTraffic { reads: n1 * n2, writes: n1 * n2 }
}

/// Traffic model of the efficient postprocess: N1*H2 complex reads
/// (2 scalars) + N^2 scalar writes.
pub fn postprocess_traffic(n1: usize, n2: usize) -> StageTraffic {
    let h2 = n2 / 2 + 1;
    StageTraffic { reads: 2 * n1 * h2, writes: n1 * n2 }
}

/// Achieved fraction of the roofline for a measured stage time.
pub fn achieved_fraction(traffic: StageTraffic, seconds: f64, roof_bw: f64) -> f64 {
    (traffic.bytes() / seconds) / roof_bw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_is_plausible() {
        let m = measure_machine(1 << 20, 3);
        // any machine this runs on moves > 100 MB/s and < 1 TB/s per core
        assert!(m.copy_bw > 1e8 && m.copy_bw < 1e12, "copy {}", m.copy_bw);
        assert!(m.triad_bw > 1e8 && m.triad_bw < 1e12, "triad {}", m.triad_bw);
    }

    #[test]
    fn traffic_models() {
        let pre = preprocess_traffic(1024, 1024);
        assert_eq!(pre.reads, 1024 * 1024);
        assert_eq!(pre.bytes(), (2.0 * 8.0) * 1024.0 * 1024.0);
        let post = postprocess_traffic(1024, 1024);
        assert_eq!(post.reads, 2 * 1024 * 513);
        assert_eq!(post.writes, 1024 * 1024);
    }

    #[test]
    fn fraction_sane() {
        let t = preprocess_traffic(256, 256);
        let f = achieved_fraction(t, 1.0, t.bytes());
        assert!((f - 1.0).abs() < 1e-12);
    }
}
