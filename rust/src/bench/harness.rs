//! Timing harness (criterion substitute): warmup + timed iterations with
//! summary statistics, plus a black_box to defeat dead-code elimination.

use std::time::Instant;

use crate::util::stats::Summary;

/// Prevent the optimizer from discarding a computed value.
#[inline(always)]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable; thin wrapper for a single import site
    std::hint::black_box(x)
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// hard cap on total measured seconds (large sizes stop early)
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 20, max_seconds: 10.0 }
    }
}

impl BenchConfig {
    /// Paper-style config: "average execution time of 100 runs".
    pub fn paper() -> BenchConfig {
        BenchConfig { warmup_iters: 5, iters: 100, max_seconds: 30.0 }
    }

    /// Quick config for CI-ish runs.
    pub fn quick() -> BenchConfig {
        BenchConfig { warmup_iters: 1, iters: 5, max_seconds: 2.0 }
    }

    /// Honor `MDDCT_BENCH_ITERS` / `MDDCT_BENCH_QUICK` env overrides.
    pub fn from_env(default: BenchConfig) -> BenchConfig {
        let mut cfg = default;
        if std::env::var("MDDCT_BENCH_QUICK").is_ok() {
            cfg = BenchConfig::quick();
        }
        if let Ok(s) = std::env::var("MDDCT_BENCH_ITERS") {
            if let Ok(n) = s.parse::<usize>() {
                cfg.iters = n.max(1);
            }
        }
        cfg
    }
}

/// Time `f` under `cfg`; returns per-iteration summaries in seconds.
pub fn time_fn(cfg: &BenchConfig, mut f: impl FnMut()) -> Summary {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let budget = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if budget.elapsed().as_secs_f64() > cfg.max_seconds && !samples.is_empty() {
            break;
        }
    }
    Summary::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_known_sleep() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_seconds: 5.0 };
        let s = time_fn(&cfg, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(s.mean >= 0.002, "mean {}", s.mean);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn respects_budget() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1000, max_seconds: 0.05 };
        let s = time_fn(&cfg, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(s.n < 1000);
    }

    #[test]
    fn env_quick_override() {
        std::env::set_var("MDDCT_BENCH_QUICK", "1");
        let cfg = BenchConfig::from_env(BenchConfig::paper());
        assert_eq!(cfg.iters, BenchConfig::quick().iters);
        std::env::remove_var("MDDCT_BENCH_QUICK");
    }
}
