//! `mddct` — CLI for the fused multi-dimensional transform service.
//!
//! Subcommands:
//!   info                          library + backend report
//!   transform --op <op> --n1 A [--n2 B] [--seed S] [--pjrt]
//!                                 run one transform on random data
//!   serve --port P [--workers W] [--max-conns C] [--pjrt]
//!         [--deadline-ms D] [--max-inflight E] [--fault SPEC]
//!         [--drain-ms G]
//!                                 TCP front-end (length-framed JSON wire
//!                                 protocol, see README); also honours
//!                                 MDDCT_PORT / MDDCT_BIND / MDDCT_MAX_CONNS /
//!                                 MDDCT_MAX_FRAME_BYTES plus the hardening
//!                                 knobs MDDCT_READ_TIMEOUT_MS /
//!                                 MDDCT_IDLE_TIMEOUT_MS / MDDCT_CONN_INFLIGHT.
//!                                 SIGINT/SIGTERM trigger a graceful drain
//!                                 bounded by --drain-ms / MDDCT_DRAIN_MS
//!                                 (default 5000). Without --port or
//!                                 MDDCT_PORT, falls back to the in-process
//!                                 throughput demo (--requests N); lifecycle
//!                                 knobs mirror MDDCT_DEADLINE_MS /
//!                                 MDDCT_MAX_INFLIGHT / MDDCT_FAULT
//!   compress --n 512 --eps 10     whole-image compression case study
//!   place --bench adaptec1 --iters 8
//!                                 electrostatic placement case study
//!   trace --op dct2d --n1 256 [--n2 N] [--requests R] [--workers W]
//!         [--out trace.json]      run traffic with tracing on, dump a
//!                                 Chrome/Perfetto trace + breakdown
//!   warmup                        pre-compile all PJRT artifacts

use mddct::apps::{Compressor, PlacementEngine, SolverBackend, ISPD2005};
use mddct::cli::Args;
use mddct::coordinator::{BatchPolicy, Router, Service, ServiceConfig, TransformOp};
use mddct::runtime::{Manifest, PjrtHandle, DEFAULT_ARTIFACT_DIR};
use mddct::server::{Server, ServerConfig};
use mddct::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("info") | None => cmd_info(&args),
        Some("transform") => cmd_transform(&args),
        Some("serve") => cmd_serve(&args),
        Some("compress") => cmd_compress(&args),
        Some("place") => cmd_place(&args),
        Some("trace") => cmd_trace(&args),
        Some("warmup") => cmd_warmup(&args),
        Some(other) => {
            eprintln!("unknown command '{other}'");
            eprintln!("commands: info transform serve compress place trace warmup");
            2
        }
    };
    std::process::exit(code);
}

fn make_router(args: &Args) -> Router {
    if args.flag_bool("pjrt") {
        match Manifest::load(args.flag_str("artifacts", DEFAULT_ARTIFACT_DIR)) {
            Ok(m) => {
                let handle =
                    PjrtHandle::spawn(args.flag_str("artifacts", DEFAULT_ARTIFACT_DIR));
                return Router::with_pjrt(handle, &m);
            }
            Err(e) => eprintln!("pjrt unavailable ({e:#}); using native backend"),
        }
    }
    Router::native_only()
}

/// Apply the request-lifecycle CLI knobs (`--deadline-ms`,
/// `--max-inflight`, `--fault`) on top of a config; the flags override
/// the env-derived defaults (`MDDCT_DEADLINE_MS` etc).
fn apply_lifecycle_flags(args: &Args, cfg: &mut ServiceConfig) {
    if let Some(ms) = args.flag("deadline-ms").and_then(|v| v.parse::<u64>().ok()) {
        cfg.default_deadline = Some(std::time::Duration::from_millis(ms));
    }
    if let Some(elems) = args.flag("max-inflight").and_then(|v| v.parse::<usize>().ok()) {
        cfg.max_inflight_elems = elems;
    }
    if let Some(spec) = args.flag("fault") {
        match mddct::coordinator::parse_spec(spec) {
            Ok(s) => mddct::coordinator::set_faults(s),
            Err(e) => eprintln!("--fault ignored: {e}"),
        }
    }
}

fn service(args: &Args) -> Service {
    let mut cfg = ServiceConfig {
        workers: args.flag_usize("workers", 4),
        batch: BatchPolicy::default(),
        ..Default::default()
    };
    apply_lifecycle_flags(args, &mut cfg);
    Service::start(cfg, make_router(args))
}

fn cmd_info(args: &Args) -> i32 {
    println!("mddct — fused MD DCT / Fourier-related transform service");
    println!("native backend : radix-2/Bluestein RFFT + fused three-stage DCT (f64)");
    match Manifest::load(args.flag_str("artifacts", DEFAULT_ARTIFACT_DIR)) {
        Ok(m) => {
            println!("artifacts      : {} entries (dtype {})", m.entries.len(), m.dtype);
            let handle = PjrtHandle::spawn(args.flag_str("artifacts", DEFAULT_ARTIFACT_DIR));
            match handle.platform() {
                Ok(p) => println!("pjrt platform  : {p}"),
                Err(e) => println!("pjrt platform  : unavailable ({e:#})"),
            }
        }
        Err(e) => println!("artifacts      : none ({e:#})"),
    }
    0
}

fn cmd_transform(args: &Args) -> i32 {
    let op_name = args.flag_str("op", "dct2d");
    let Some(op) = TransformOp::parse(op_name) else {
        eprintln!("unknown op '{op_name}'");
        return 2;
    };
    let n1 = args.flag_usize("n1", 256);
    let shape = match op.rank() {
        1 => vec![n1],
        2 => vec![n1, args.flag_usize("n2", n1)],
        _ => vec![n1, args.flag_usize("n2", n1), args.flag_usize("n3", n1)],
    };
    let numel: usize = shape.iter().product();
    let mut rng = Rng::new(args.flag_usize("seed", 42) as u64);
    let data = rng.normal_vec(numel);
    let svc = service(args);
    match svc.transform(op, shape.clone(), data) {
        Ok(r) => {
            println!(
                "{op_name} {shape:?}: backend={} latency={:.3} ms  checksum={:.6e}",
                r.backend,
                r.latency * 1e3,
                r.output.iter().sum::<f64>()
            );
            0
        }
        Err(e) => {
            eprintln!("transform failed: {e}");
            1
        }
    }
}

/// Dependency-free SIGINT/SIGTERM latch: the handler only flips an
/// atomic, the serve loop polls it and runs the drain from the main
/// thread (nothing async-signal-unsafe happens in the handler).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static STOP: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_signum: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    /// Install the latch for SIGINT (2) and SIGTERM (15).
    #[allow(clippy::fn_to_numeric_cast_any)]
    pub fn install() {
        unsafe {
            signal(2, on_signal as usize);
            signal(15, on_signal as usize);
        }
    }

    pub fn stopped() -> bool {
        STOP.load(Ordering::SeqCst)
    }
}

fn cmd_serve(args: &Args) -> i32 {
    // TCP mode: `--port P` (0 = ephemeral) or the MDDCT_PORT env knob
    let port_flag = args.flag_opt_usize("port");
    if port_flag.is_some() || std::env::var_os("MDDCT_PORT").is_some() {
        let mut cfg = ServerConfig::default();
        if let Some(p) = port_flag.and_then(|p| u16::try_from(p).ok()) {
            cfg.port = p;
        }
        if let Some(c) = args.flag_opt_usize("max-conns") {
            cfg.max_conns = c;
        }
        let grace_ms = args
            .flag_opt_usize("drain-ms")
            .or_else(|| mddct::util::env_usize("MDDCT_DRAIN_MS"))
            .unwrap_or(5000);
        let svc = std::sync::Arc::new(service(args));
        #[allow(unused_mut)]
        let mut server = match Server::start(cfg, svc) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve failed to bind: {e}");
                return 1;
            }
        };
        println!("mddct serving on {} (frame = 4-byte BE length + JSON)", server.addr());
        #[cfg(unix)]
        {
            sig::install();
            while !sig::stopped() {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            eprintln!("signal received; draining (up to {grace_ms} ms) ...");
            let grace = std::time::Duration::from_millis(grace_ms as u64);
            if server.drain(grace) {
                eprintln!("drained cleanly");
            } else {
                eprintln!("drain deadline hit; remaining requests answered shutting_down");
            }
            return 0;
        }
        #[cfg(not(unix))]
        loop {
            std::thread::park();
        }
    }
    // fallback: in-process throughput demo
    let requests = args.flag_usize("requests", 256);
    let n = args.flag_usize("n", 256);
    let svc = service(args);
    let mut rng = Rng::new(7);
    let payloads: Vec<Vec<f64>> =
        (0..requests).map(|_| rng.normal_vec(n * n)).collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let mut shed = 0usize;
    for p in payloads {
        match svc.submit(TransformOp::Dct2d, vec![n, n], p) {
            Ok(h) => handles.push(h),
            Err(e) if e.is_retryable() => shed += 1,
            Err(e) => {
                eprintln!("submit failed: {e}");
                return 1;
            }
        }
    }
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} dct2d {n}x{n} in {dt:.3}s  ({:.1} req/s, {shed} shed)",
        ok as f64 / dt
    );
    println!("metrics: {}", svc.metrics.snapshot());
    0
}

fn cmd_compress(args: &Args) -> i32 {
    let n = args.flag_usize("n", 512);
    let eps = args.flag_f64("eps", 10.0);
    let img = mddct::apps::synthetic_image(n, n, 11);
    let rep = Compressor::new(n, n).report(&img, eps);
    println!(
        "compress {n}x{n} eps={eps}: sparsity={:.1}%  psnr={:.2} dB",
        rep.sparsity * 100.0,
        rep.psnr_db
    );
    0
}

fn cmd_place(args: &Args) -> i32 {
    let name = args.flag_str("bench", "adaptec1");
    let Some(b) = ISPD2005.iter().find(|b| b.name == name) else {
        eprintln!("unknown benchmark '{name}'");
        return 2;
    };
    let iters = args.flag_usize("iters", 4);
    let backend = if args.flag_str("backend", "fused") == "rowcol" {
        SolverBackend::RowColumn
    } else {
        SolverBackend::Fused
    };
    let mut circuit = b.generate(1);
    let engine = PlacementEngine::new(b.grid, backend);
    println!("{name}: {} cells, {}x{} grid", circuit.cells(), b.grid, b.grid);
    for r in engine.run(&mut circuit, iters) {
        println!(
            "  iter {:2}: transform {:.2} ms, other {:.2} ms, overflow {:.4e}",
            r.iter,
            r.transform_seconds * 1e3,
            r.other_seconds * 1e3,
            r.overflow
        );
    }
    0
}

fn cmd_trace(args: &Args) -> i32 {
    let op_name = args.flag_str("op", "dct2d");
    let Some(op) = TransformOp::parse(op_name) else {
        eprintln!("unknown op '{op_name}'");
        return 2;
    };
    let n1 = args.flag_usize("n1", 256);
    let shape = match op.rank() {
        1 => vec![n1],
        2 => vec![n1, args.flag_usize("n2", n1)],
        _ => vec![n1, args.flag_usize("n2", n1), args.flag_usize("n3", n1)],
    };
    let numel: usize = shape.iter().product();
    let requests = args.flag_usize("requests", 32);
    let out_path = args.flag_str("out", "trace.json");
    let mut cfg = ServiceConfig {
        workers: args.flag_usize("workers", 4),
        batch: BatchPolicy::default(),
        trace: true,
        ..Default::default()
    };
    apply_lifecycle_flags(args, &mut cfg);
    let svc = Service::start(cfg, make_router(args));
    let mut rng = Rng::new(args.flag_usize("seed", 42) as u64);
    let reqs: Vec<_> = (0..requests).map(|_| (op, shape.clone(), rng.normal_vec(numel))).collect();
    let t0 = std::time::Instant::now();
    let out = match svc.transform_many(reqs) {
        Ok(out) => out,
        Err(e) => {
            eprintln!("trace traffic failed: {e}");
            return 1;
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    println!("traced {} {op_name} {shape:?} requests in {dt:.3}s", out.len());
    println!("snapshot: {}", svc.snapshot());
    match mddct::obs::write_chrome_trace(out_path) {
        Ok(()) => {
            println!("chrome trace written to {out_path} (load in ui.perfetto.dev)");
            0
        }
        Err(e) => {
            eprintln!("failed to write {out_path}: {e}");
            1
        }
    }
}

fn cmd_warmup(args: &Args) -> i32 {
    let dir = args.flag_str("artifacts", DEFAULT_ARTIFACT_DIR);
    let m = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e:#}");
            return 1;
        }
    };
    let handle = PjrtHandle::spawn(dir);
    let mut total = 0.0;
    for name in m.entries.keys() {
        match handle.warmup(name) {
            Ok(s) => {
                total += s;
                println!("  {name}: compiled in {:.2}s", s);
            }
            Err(e) => {
                eprintln!("  {name}: FAILED {e:#}");
                return 1;
            }
        }
    }
    println!("warmed {} executables in {total:.1}s total", m.entries.len());
    0
}
