//! # mddct — fused multi-dimensional Fourier-related transforms
//!
//! Production-shaped reproduction of *"A New Acceleration Paradigm for
//! Discrete Cosine Transform and Other Fourier-Related Transforms"*
//! (Jiang, Gu, Pan; 2021): MD DCT/IDCT/IDXST computed as a single fused
//! `preprocess -> MD RFFT -> postprocess` pipeline instead of the
//! row-column method.
//!
//! Layers:
//! * [`fft`]  — native FFT substrate: power-of-two kernels behind a
//!   per-plan selector ([`fft::FftKernel`] — scalar radix-2 reference
//!   vs split-radix/radix-4 SoA butterflies on planar scratch, panel-
//!   blocked column transforms), Bluestein for arbitrary N, RFFT,
//!   2D/3D, plan cache
//! * [`dct`]  — the paper's transforms: fused three-stage + baselines,
//!   plus the generic-element (`f32`) instantiations ([`dct::Dct2F32`])
//! * [`layout`] — layout descriptors ([`layout::Layout`]): element type
//!   (`f64`/`f32`), per-axis strides, batch stride — the parameter the
//!   strided/zero-copy plan entry points take
//! * [`parallel`] — work-sharing execution layer: process-wide scoped
//!   thread pool, chunked parallel loops, parallel tiled transpose, the
//!   [`parallel::ExecPolicy`] every plan carries (`Serial` /
//!   `Threads(n)` / `Auto`), and the [`parallel::ShardPolicy`] band
//!   decomposition knob
//! * [`runtime`] — PJRT executor for the JAX/Pallas AOT artifacts
//! * [`coordinator`] — transform service: plans, batching, band-sharded
//!   execution of large requests ([`coordinator::shard`]), workers,
//!   metrics
//! * [`server`] — blocking-TCP wire front-end: length-framed
//!   incremental JSON ([`server::proto`]) mapped 1:1 onto the service
//!   lifecycle (typed error frames for deadline / shed / panic)
//! * [`apps`] — image compression & electrostatic placement built on top
//! * [`bench`] — harness regenerating every paper table/figure
//! * [`obs`]  — cross-layer tracing: zero-overhead-when-disabled spans
//!   through every hot layer, a live per-(op, shape) stage breakdown,
//!   and Chrome trace-event export (Perfetto-loadable)
//! * [`util`] — offline substrates (json, rng, property testing, stats)
//!
//! Execution model: plans are built per shape (twiddles + FFT plans
//! precomputed), then executed many times. Each plan's `ExecPolicy`
//! decides how its batched stages fan out over the shared thread pool —
//! the service's workers reuse that same pool, so a single process has
//! exactly one set of compute threads no matter how many plans, workers,
//! or concurrent requests are live. A plan's `ShardPolicy` additionally
//! pins how many band work items each banded stage becomes — row bands
//! in 2D, dim-0 i-slabs in 3D — which is how the coordinator splits one
//! huge request across the pool while small requests keep flowing (see
//! `ARCHITECTURE.md` at the repo root for the full layer map and shard
//! lifecycle).
//!
//! ```
//! use mddct::dct::{Dct2, Idct2};
//!
//! let (n1, n2) = (8, 8);
//! let x = vec![1.0; n1 * n2];
//! let mut y = vec![0.0; n1 * n2];
//! Dct2::new(n1, n2).forward(&x, &mut y);
//! // a constant image concentrates all energy in the DC bin
//! assert!((y[0] - 4.0 * (n1 * n2) as f64).abs() < 1e-9);
//!
//! let mut back = vec![0.0; n1 * n2];
//! Idct2::new(n1, n2).forward(&y, &mut back);
//! assert!(back.iter().all(|v| (v - 1.0).abs() < 1e-9));
//! ```

pub mod dct;
pub mod fft;
pub mod layout;
pub mod util;
// remaining layers added below as they land
pub mod apps;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod obs;
pub mod parallel;
pub mod runtime;
pub mod server;
