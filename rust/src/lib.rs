//! # mddct — fused multi-dimensional Fourier-related transforms
//!
//! Production-shaped reproduction of *"A New Acceleration Paradigm for
//! Discrete Cosine Transform and Other Fourier-Related Transforms"*
//! (Jiang, Gu, Pan; 2021): MD DCT/IDCT/IDXST computed as a single fused
//! `preprocess -> MD RFFT -> postprocess` pipeline instead of the
//! row-column method.
//!
//! Layers:
//! * [`fft`]  — native FFT substrate: power-of-two kernels behind a
//!   per-plan selector ([`fft::FftKernel`] — scalar radix-2 reference
//!   vs split-radix/radix-4 SoA butterflies on planar scratch, panel-
//!   blocked column transforms), Bluestein for arbitrary N, RFFT,
//!   2D/3D, plan cache
//! * [`dct`]  — the paper's transforms: fused three-stage + baselines
//! * [`parallel`] — work-sharing execution layer: process-wide scoped
//!   thread pool, chunked parallel loops, parallel tiled transpose, and
//!   the [`parallel::ExecPolicy`] every plan carries (`Serial` /
//!   `Threads(n)` / `Auto`)
//! * [`runtime`] — PJRT executor for the JAX/Pallas AOT artifacts
//! * [`coordinator`] — transform service: plans, batching, workers, metrics
//! * [`apps`] — image compression & electrostatic placement built on top
//! * [`bench`] — harness regenerating every paper table/figure
//! * [`util`] — offline substrates (json, rng, property testing, stats)
//!
//! Execution model: plans are built per shape (twiddles + FFT plans
//! precomputed), then executed many times. Each plan's `ExecPolicy`
//! decides how its batched stages fan out over the shared thread pool —
//! the service's workers reuse that same pool, so a single process has
//! exactly one set of compute threads no matter how many plans, workers,
//! or concurrent requests are live.

pub mod dct;
pub mod fft;
pub mod util;
// remaining layers added below as they land
pub mod apps;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod parallel;
pub mod runtime;
