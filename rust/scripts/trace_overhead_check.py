#!/usr/bin/env python3
"""Disabled-tracing overhead gate: compare the default build's
trace-overhead bench against a ``--features trace-off`` build.

Both files come from ``benches/trace_overhead.rs``:

    { "bench": "trace_overhead", "variant": "default" | "trace_off",
      "rows": [ {"n": ..., "min_ms": ..., "mean_ms": ...}, ... ] }

The default build keeps every span site but tracing disabled (one
relaxed atomic load per site); the trace-off build deletes the sites at
compile time. For each size present in both files the gate compares the
*min* timing — the least noise-sensitive estimator of the per-call
floor, where a constant per-site cost would show — and fails when the
**median ratio** across sizes exceeds ``1 + threshold/100``. The median
keeps one noisy size on a shared runner from failing the gate alone.

Usage:
    trace_overhead_check.py --default BENCH_trace_overhead.json \\
        --trace-off BENCH_trace_overhead_off.json [--threshold 2]

Exit status 1 iff the overhead exceeds the threshold.
"""

import argparse
import json
import sys


def load_mins(path):
    """{n: min_ms} from a trace_overhead bench JSON."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        if isinstance(row, dict) and isinstance(row.get("min_ms"), (int, float)):
            out[row["n"]] = row["min_ms"]
    return out


def median(xs):
    xs = sorted(xs)
    mid = len(xs) // 2
    if len(xs) % 2:
        return xs[mid]
    return (xs[mid - 1] + xs[mid]) / 2.0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--default", dest="default_path", required=True,
                    help="bench JSON from the default build (sites present, tracing off)")
    ap.add_argument("--trace-off", dest="off_path", required=True,
                    help="bench JSON from the --features trace-off build")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when the median min-time ratio exceeds this percent "
                    "(default 2)")
    args = ap.parse_args()

    default_mins = load_mins(args.default_path)
    off_mins = load_mins(args.off_path)
    shared = sorted(set(default_mins) & set(off_mins))
    if not shared:
        print("trace_overhead_check: no shared sizes between the two files",
              file=sys.stderr)
        return 1

    ratios = []
    for n in shared:
        d, o = default_mins[n], off_mins[n]
        if o <= 0:
            print(f"  n={n}: trace-off min is {o} ms; skipping")
            continue
        ratio = d / o
        ratios.append(ratio)
        print(f"  n={n}: default {d:.4f} ms vs trace-off {o:.4f} ms "
              f"({(ratio - 1) * 100:+.2f}%)")
    if not ratios:
        print("trace_overhead_check: no comparable sizes", file=sys.stderr)
        return 1

    med = median(ratios)
    limit = 1.0 + args.threshold / 100.0
    print(f"\ntrace_overhead_check: median overhead {(med - 1) * 100:+.2f}% "
          f"over {len(ratios)} sizes (limit +{args.threshold:.1f}%)")
    if med > limit:
        print("FAIL: disabled tracing costs more than the gate allows",
              file=sys.stderr)
        return 1
    print("ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
