#!/usr/bin/env python3
"""Markdown dead-link check: every *relative* link target in the repo's
markdown docs must exist on disk.

Scans all ``*.md`` files under the given root (skipping VCS/target
dirs), extracts inline ``[text](target)`` links, and resolves each
relative target against the containing file's directory. External
schemes (http/https/mailto), pure in-page anchors (``#...``), and
autolinks are ignored; a ``path#anchor`` target is checked for the
path part only. Exit status 1 iff at least one target is missing —
renaming DESIGN.md or a bench artifact must not leave dangling
references in README/ARCHITECTURE.

Usage:
    check_links.py [ROOT]
"""

import os
import re
import sys

SKIP_DIRS = {".git", "target", ".bench-baseline", "node_modules", "__pycache__"}

# Generated reference dumps (arxiv retrieval output), not docs we
# author: their figure links point at assets that were never vendored.
SKIP_FILES = {"PAPERS.md", "SNIPPETS.md"}

# inline links only: [text](target). Reference-style links are not used
# in this repo; images ![alt](path) match too via the optional bang.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def iter_markdown(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.lower().endswith(".md") and name not in SKIP_FILES:
                yield os.path.join(dirpath, name)


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    missing = []
    files = checked = 0
    for md in iter_markdown(root):
        files += 1
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            checked += 1
            resolved = os.path.normpath(os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                missing.append(f"{os.path.relpath(md, root)}: ({target}) -> {resolved}")
    print(f"check_links: {files} markdown files, {checked} relative links")
    if missing:
        print(f"\n{len(missing)} dead link(s):", file=sys.stderr)
        for m in missing:
            print(f"  FAIL {m}", file=sys.stderr)
        return 1
    print("no dead links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
