#!/usr/bin/env python3
"""Bench-trend gate: diff the current BENCH_*.json artifacts against the
previous commit's set and fail on time regressions.

Every bench binary in this repo emits the same shape of JSON:

    { "bench": "...", ..., "rows": [ {<identity fields>, <*_ms fields>,
      "speedup": ...}, ... ] }

A row's *identity* is every field whose key is not a measurement; a
measurement is any key ending in ``_ms`` or starting with ``speedup``
(table5 calls its ratio ``speedup_vs_serial`` — a measured float must
never leak into identity or the row misses its baseline every run).
For each row present in both the baseline and the current artifact,
each ``*_ms`` measurement must not exceed
``baseline * (1 + threshold/100)``; rows or files missing on either
side are reported but never fail the gate (first run, renamed benches,
and resized quick modes all stay green).

Usage:
    bench_diff.py --baseline DIR --current DIR [--threshold 15]
                  [--min-abs-ms 0.05]

Exit status 1 iff at least one regression was found.
"""

import argparse
import glob
import json
import os
import sys


def is_measurement(key):
    """Whether a row field is a measured value, not part of its identity."""
    return key.endswith("_ms") or key.startswith("speedup")


def row_identity(row):
    """Hashable identity of a row: all non-measurement fields."""
    return tuple(sorted((k, v) for k, v in row.items() if not is_measurement(k)))


def load_rows(path):
    """rows list of a bench JSON, indexed by identity (None if unusable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"  ! {os.path.basename(path)}: unreadable ({e}); skipping")
        return None
    rows = doc.get("rows")
    if not isinstance(rows, list):
        print(f"  ! {os.path.basename(path)}: no rows[]; skipping")
        return None
    indexed = {}
    for row in rows:
        if isinstance(row, dict):
            indexed[row_identity(row)] = row
    return indexed


def fmt_identity(identity):
    return " ".join(f"{k}={v}" for k, v in identity)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="dir with the previous BENCH_*.json set")
    ap.add_argument("--current", required=True, help="dir with the fresh BENCH_*.json set")
    ap.add_argument(
        "--threshold",
        type=float,
        default=15.0,
        help="fail when a *_ms value grows more than this percent (default 15)",
    )
    ap.add_argument(
        "--min-abs-ms",
        type=float,
        default=0.05,
        help="ignore regressions smaller than this many ms (timer-noise floor "
        "for quick-mode runs on shared CI runners)",
    )
    args = ap.parse_args()

    current_files = sorted(glob.glob(os.path.join(args.current, "BENCH_*.json")))
    if not current_files:
        print(f"bench_diff: no BENCH_*.json under {args.current}", file=sys.stderr)
        return 1

    regressions = []
    compared = 0
    for cur_path in current_files:
        name = os.path.basename(cur_path)
        base_path = os.path.join(args.baseline, name)
        print(f"{name}:")
        if not os.path.exists(base_path):
            print("  - no baseline (first run for this bench); skipping")
            continue
        cur_rows = load_rows(cur_path)
        base_rows = load_rows(base_path)
        if cur_rows is None or base_rows is None:
            continue
        file_regressions = 0
        for identity, cur in cur_rows.items():
            base = base_rows.get(identity)
            if base is None:
                print(f"  - new row [{fmt_identity(identity)}]; skipping")
                continue
            for key, cur_val in cur.items():
                if not key.endswith("_ms") or key not in base:
                    continue
                base_val = base[key]
                if not isinstance(cur_val, (int, float)) or not isinstance(
                    base_val, (int, float)
                ):
                    continue
                compared += 1
                grew = cur_val - base_val
                limit = base_val * (1.0 + args.threshold / 100.0)
                if cur_val > limit and grew > args.min_abs_ms:
                    pct = 100.0 * grew / base_val if base_val > 0 else float("inf")
                    file_regressions += 1
                    regressions.append(
                        f"{name} [{fmt_identity(identity)}] {key}: "
                        f"{base_val:.4f} -> {cur_val:.4f} ms (+{pct:.1f}%)"
                    )
        if file_regressions:
            print(f"  - {file_regressions} REGRESSION(S) in {len(cur_rows)} rows")
        else:
            print(f"  - ok ({len(cur_rows)} rows)")

    print(f"\nbench_diff: compared {compared} measurements "
          f"(threshold +{args.threshold:.0f}%, noise floor {args.min_abs_ms} ms)")
    if regressions:
        print(f"\n{len(regressions)} regression(s):", file=sys.stderr)
        for r in regressions:
            print(f"  FAIL {r}", file=sys.stderr)
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
