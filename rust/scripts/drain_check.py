#!/usr/bin/env python3
"""End-to-end graceful-drain check for `mddct serve`, driven over a raw
socket by an outside observer (no crate code on the client side).

Spawns the release binary on an ephemeral port, and from a plain TCP
socket speaking the 4-byte-BE-length + JSON framing:

1. hits the `health` route (must report ``ok`` / ``ready: true``),
2. runs one 8x8 dct2d transform (must answer ``ok`` with 64 outputs),
3. sends the process SIGTERM, and
4. asserts the drain contract: the idle connection receives one final
   typed ``shutting_down`` error frame followed by EOF, and the process
   itself exits 0 (having logged ``drained cleanly``) within the grace
   window.

Usage (from the `rust/` directory, binary already built):
    drain_check.py [--timeout SECONDS]

Exit status 1 with a diagnostic on any broken step.
"""

import argparse
import json
import re
import signal
import socket
import struct
import subprocess
import sys
import time


def send_frame(sock, body):
    raw = body.encode("utf-8")
    sock.sendall(struct.pack(">I", len(raw)) + raw)


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None  # EOF mid-read (or clean EOF at n bytes short)
        buf += chunk
    return buf


def recv_frame(sock):
    """One length-prefixed frame, or None on clean EOF."""
    prefix = recv_exact(sock, 4)
    if prefix is None:
        return None
    (length,) = struct.unpack(">I", prefix)
    body = recv_exact(sock, length)
    if body is None:
        raise RuntimeError("EOF inside a frame body")
    return json.loads(body.decode("utf-8"))


def fail(proc, msg):
    proc.kill()
    out, err = proc.communicate(timeout=10)
    print(f"FAIL: {msg}", file=sys.stderr)
    print(f"--- server stdout ---\n{out}", file=sys.stderr)
    print(f"--- server stderr ---\n{err}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="overall deadline for the whole scenario")
    args = ap.parse_args()

    proc = subprocess.Popen(
        ["cargo", "run", "--release", "-q", "--",
         "serve", "--port", "0", "--workers", "1", "--drain-ms", "5000"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, bufsize=1,
    )
    deadline = time.monotonic() + args.timeout

    # the serve banner carries the ephemeral address
    banner = proc.stdout.readline()
    m = re.search(r"mddct serving on (\S+):(\d+)", banner)
    if not m:
        fail(proc, f"no serve banner, got: {banner!r}")
    host, port = m.group(1), int(m.group(2))

    sock = socket.create_connection((host, port), timeout=10)
    sock.settimeout(10)

    # 1. health route before the drain: ok / ready
    send_frame(sock, '{"op":"health"}')
    reply = recv_frame(sock)
    if reply is None or reply.get("health") != "ok" or reply.get("ready") is not True:
        fail(proc, f"pre-drain health reply wrong: {reply!r}")

    # 2. one real transform completes over the wire
    data = ",".join(["0.5"] * 64)
    send_frame(sock, f'{{"id":7,"op":"dct2d","shape":[8,8],"batch":1,"data":[{data}]}}')
    reply = recv_frame(sock)
    if reply is None or reply.get("ok") is not True or reply.get("id") != 7:
        fail(proc, f"transform reply wrong: {reply!r}")
    if len(reply.get("data", [])) != 64:
        fail(proc, f"transform returned {len(reply.get('data', []))} values, wanted 64")

    # 3. graceful shutdown: SIGTERM, then the drain contract on the
    # still-open idle connection — one typed shutting_down frame, EOF
    proc.send_signal(signal.SIGTERM)
    goodbye = recv_frame(sock)
    if goodbye is None:
        fail(proc, "connection closed without the shutting_down goodbye frame")
    if goodbye.get("ok") is not False or goodbye.get("error") != "shutting_down":
        fail(proc, f"goodbye frame wrong: {goodbye!r}")
    if recv_frame(sock) is not None:
        fail(proc, "expected EOF after the goodbye frame")
    sock.close()

    # 4. the process itself exits 0 within the grace window
    try:
        code = proc.wait(timeout=max(1.0, deadline - time.monotonic()))
    except subprocess.TimeoutExpired:
        fail(proc, "server did not exit after SIGTERM")
    out, err = proc.communicate(timeout=10)
    if code != 0:
        print(f"FAIL: server exited {code}", file=sys.stderr)
        print(f"--- server stderr ---\n{err}", file=sys.stderr)
        sys.exit(1)
    if "drained cleanly" not in err:
        print(f"FAIL: no 'drained cleanly' log; stderr:\n{err}", file=sys.stderr)
        sys.exit(1)
    print("drain_check: health + transform + SIGTERM drain contract all held")


if __name__ == "__main__":
    main()
