//! Table III — naive vs efficient 2D DCT postprocessing: operation
//! counts, arithmetic intensity (analytic model), and the measured
//! speedup of the efficient kernel that the model predicts.
//!
//! Run: `cargo bench --bench table3_arithmetic_intensity`

use mddct::bench::intensity::{naive_row, ours_row};
use mddct::bench::{black_box, time_fn, BenchConfig, Table};
use mddct::dct::Dct2;
use mddct::fft::{onesided_len, C64};
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

fn main() {
    let (n1, n2) = (1024usize, 1024usize);
    println!("\nTable III: 2D DCT postprocessing cost model (N1 = N2 = {n1})\n");

    let rows = [naive_row(n1, n2), ours_row(n1, n2)];
    let mut t = Table::new(&[
        "method", "#thread", "#read/t", "#mul/t", "#add/t", "AI", "#read", "#mul", "#add",
    ]);
    for r in &rows {
        t.row(&[
            r.method.to_string(),
            format!("{:.0}", r.threads),
            format!("{:.0}", r.reads_per_thread),
            format!("{:.0}", r.muls_per_thread),
            format!("{:.0}", r.adds_per_thread),
            format!("{:.2}", r.arithmetic_intensity()),
            format!("{:.2e}", r.total_reads),
            format!("{:.2e}", r.total_muls),
            format!("{:.2e}", r.total_adds),
        ]);
    }
    t.print();
    println!(
        "model: reads x{:.1}, muls x{:.1}, adds x{:.2} in favor of our method",
        rows[0].total_reads / rows[1].total_reads,
        rows[0].total_muls / rows[1].total_muls,
        rows[0].total_adds / rows[1].total_adds
    );

    // measured: the two postprocess kernels on a real spectrum
    let cfg = BenchConfig::from_env(BenchConfig::default());
    // serial kernel: the table models single-thread arithmetic intensity
    let plan = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
    let mut rng = Rng::new(3);
    let h2 = onesided_len(n2);
    let spec: Vec<C64> =
        (0..n1 * h2).map(|_| C64::new(rng.normal(), rng.normal())).collect();
    let mut out = vec![0.0; n1 * n2];
    let eff = time_fn(&cfg, || {
        plan.postprocess(&spec, &mut out);
        black_box(&out);
    });
    let naive = time_fn(&cfg, || {
        plan.postprocess_naive(&spec, &mut out);
        black_box(&out);
    });
    println!(
        "\nmeasured postprocess: naive {:.3} ms vs ours {:.3} ms  ({:.2}x; the model's \
         4x read reduction is the driver)",
        naive.mean * 1e3,
        eff.mean * 1e3,
        naive.mean / eff.mean
    );
}
