//! Table IV — execution time of the four 1D-DCT-via-FFT algorithms,
//! N = 2^14 .. 2^18 (microseconds).
//!
//! Paper shape: the N-point algorithm wins at every size, with the gap
//! widening as N grows (it transforms 1/4 the points of the 4N method).
//!
//! Run: `cargo bench --bench table4_1d_algorithms`
//! Set MDDCT_TABLE4_PJRT=1 to also time the AOT artifacts.

use mddct::bench::{black_box, time_fn, us, BenchConfig, Table};
use mddct::dct::{Algo1d, Dct1d};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    println!("\nTable IV: four algorithms of 1D DCT via 1D FFT (microseconds)\n");

    let sizes: Vec<usize> = (14..=18).map(|e| 1usize << e).collect();
    let headers: Vec<String> = std::iter::once("Input size N".to_string())
        .chain(Algo1d::ALL.iter().map(|a| a.name().to_string()))
        .collect();
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let mut n_wins = true;
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n);
        let mut out = vec![0.0; n];
        let mut row = vec![format!("2^{}", n.trailing_zeros())];
        let mut times = Vec::new();
        for algo in Algo1d::ALL {
            let plan = Dct1d::new(n, algo);
            let s = time_fn(&cfg, || {
                plan.forward(&x, &mut out);
                black_box(&out);
            });
            times.push(s.mean);
            row.push(us(s.mean));
        }
        n_wins &= times[3]
            <= *times[..3].iter().min_by(|a, b| a.partial_cmp(b).unwrap()).unwrap() * 1.05;
        t.row(&row);
    }
    t.print();
    println!(
        "shape check (paper): N-point fastest at every size -> {}",
        if n_wins { "REPRODUCED" } else { "NOT reproduced (see EXPERIMENTS.md)" }
    );

    if std::env::var("MDDCT_TABLE4_PJRT").is_ok() {
        pjrt_variant(&cfg);
    }
}

/// Same comparison through the AOT artifacts (XLA's DUCC FFT, f32).
fn pjrt_variant(cfg: &BenchConfig) {
    use mddct::runtime::{Manifest, PjrtHandle, DEFAULT_ARTIFACT_DIR};
    let Ok(_m) = Manifest::load(DEFAULT_ARTIFACT_DIR) else {
        println!("(artifacts missing; skipping PJRT variant)");
        return;
    };
    let handle = PjrtHandle::spawn(DEFAULT_ARTIFACT_DIR);
    println!("\nPJRT artifact variant (f32, XLA DUCC FFT), microseconds:");
    let mut t = Table::new(&["N", "4N", "Mirrored 2N", "Padded 2N", "N-point"]);
    for n in [1024usize, 4096, 16384] {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n);
        let mut row = vec![n.to_string()];
        for name in [
            format!("dct1d_4n_{n}"),
            format!("dct1d_2n_mirror_{n}"),
            format!("dct1d_2n_pad_{n}"),
            format!("dct1d_n_{n}"),
        ] {
            let _ = handle.run(&name, vec![x.clone()]); // warm compile
            let s = time_fn(cfg, || {
                black_box(handle.run(&name, vec![x.clone()]).unwrap());
            });
            row.push(us(s.mean));
        }
        t.row(&row);
    }
    t.print();
}
