//! Batched many-small-transform bench: the batch execution engine vs a
//! solo-forward loop on the millions-of-tiny-blocks workload (JPEG-style
//! 8x8/16x16/32x32 tiles, STFT-frame shapes).
//!
//! Two sections:
//! * `batch` rows — `Dct2::forward_batch` over B packed blocks vs B solo
//!   `forward` calls on the same plan, per block size x batch size x
//!   exec policy (serial isolates the per-call dispatch overhead the
//!   batch engine amortizes; auto additionally lets the batch fan out
//!   across the pool, which a sub-threshold solo transform never can);
//! * `alloc` rows — the pooled/prewarmed single-transform hot path vs
//!   the same call forced cold (`scratch::clear_thread_pool` before
//!   every iteration), i.e. the seed's allocate-per-call behaviour.
//!
//! Emits a human table plus machine-readable `BENCH_batch.json`
//! (override the path with `MDDCT_BENCH_BATCH_JSON`); the bench-diff CI
//! gate tracks every row. `MDDCT_BENCH_QUICK=1` runs a CI-sized subset.
//!
//! Run: `cargo bench --bench batch`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::Dct2;
use mddct::parallel::{default_threads, ExecPolicy};
use mddct::util::rng::Rng;
use mddct::util::scratch;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let blocks: &[usize] = if quick { &[8, 16] } else { &[8, 16, 32] };
    let batches: &[usize] = if quick { &[64, 1024] } else { &[1, 16, 64, 256, 1024, 4096] };
    println!(
        "\nBatched many-small-transform engine: forward_batch vs looped solo forward \
         ({} pool threads under auto)\n",
        default_threads()
    );

    let mut t = Table::new(&["n", "batch", "exec", "solo ms", "batched ms", "speedup"]);
    let mut json_rows: Vec<String> = Vec::new();

    for &n in blocks {
        for &batch in batches {
            let mut rng = Rng::new((n * 1000 + batch) as u64);
            let xs = rng.normal_vec(n * n * batch);
            let numel = n * n;
            for (label, exec) in
                [("serial", ExecPolicy::Serial), ("auto", ExecPolicy::Auto)]
            {
                let plan = Dct2::with_policy(n, n, exec);
                let mut out = vec![0.0; numel * batch];
                // correctness gate before timing: batched == solo loop
                let mut want = vec![0.0; numel * batch];
                for (b, w) in want.chunks_mut(numel).enumerate() {
                    plan.forward(&xs[b * numel..(b + 1) * numel], w);
                }
                plan.forward_batch(&xs, &mut out, batch);
                assert_eq!(out, want, "batched diverged at n={n} batch={batch}");

                let solo = time_fn(&cfg, || {
                    for (b, o) in out.chunks_mut(numel).enumerate() {
                        plan.forward(&xs[b * numel..(b + 1) * numel], o);
                    }
                    black_box(&out);
                })
                .mean;
                let batched = time_fn(&cfg, || {
                    plan.forward_batch(&xs, &mut out, batch);
                    black_box(&out);
                })
                .mean;
                let speedup = solo / batched;
                t.row(&[
                    n.to_string(),
                    batch.to_string(),
                    label.to_string(),
                    ms(solo),
                    ms(batched),
                    format!("{speedup:.2}x"),
                ]);
                json_rows.push(format!(
                    "{{\"section\": \"batch\", \"n\": {n}, \"batch\": {batch}, \
                     \"exec\": \"{label}\", \"solo_ms\": {:.6}, \"batched_ms\": {:.6}, \
                     \"speedup\": {speedup:.4}}}",
                    solo * 1e3,
                    batched * 1e3
                ));
            }
        }
    }

    // ---- alloc-free vs seed-style allocate-per-call -------------------
    let mut ta = Table::new(&["n", "pooled ms", "cold-alloc ms", "speedup"]);
    for &n in blocks {
        let mut rng = Rng::new(n as u64 + 5000);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];
        let plan = Dct2::with_policy(n, n, ExecPolicy::Serial);
        let pooled = time_fn(&cfg, || {
            plan.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        let cold = time_fn(&cfg, || {
            // drop every retained buffer first: each stage allocates
            // afresh, which is what every call paid in the seed tree
            scratch::clear_thread_pool();
            plan.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        let speedup = cold / pooled;
        ta.row(&[n.to_string(), ms(pooled), ms(cold), format!("{speedup:.2}x")]);
        json_rows.push(format!(
            "{{\"section\": \"alloc\", \"n\": {n}, \"pooled_ms\": {:.6}, \
             \"cold_alloc_ms\": {:.6}, \"speedup\": {speedup:.4}}}",
            pooled * 1e3,
            cold * 1e3
        ));
    }

    t.print();
    println!("\nSingle transform: pooled/prewarmed vs cold-pool (allocate per call)\n");
    ta.print();

    let path = std::env::var("MDDCT_BENCH_BATCH_JSON")
        .unwrap_or_else(|_| "BENCH_batch.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"batch\",\n  \"threads\": {},\n  \"unit\": \"forward_ms\",\n  \
         \"rows\": [\n    {}\n  ]\n}}\n",
        default_threads(),
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
