//! Disabled-path tracing overhead: the default build (span sites
//! present, tracing off — each site costs one relaxed atomic load)
//! versus a `--features trace-off` build (sites compiled out).
//!
//! CI runs this bench twice — once per build — and
//! `scripts/trace_overhead_check.py` gates the per-size *min* timing
//! ratio at < 2%. Min, not mean: the minimum over many iterations is
//! the least noise-sensitive estimator of the true per-call floor,
//! which is where a constant per-site cost would show.
//!
//! Tracing is explicitly forced off here regardless of `MDDCT_TRACE`:
//! this bench measures the cost of the *disabled* instrumentation, not
//! of recording.
//!
//! Emits `BENCH_trace_overhead.json` (override with
//! `MDDCT_BENCH_TRACE_JSON`); `MDDCT_BENCH_QUICK=1` runs a CI-sized
//! subset.
//!
//! Run: `cargo bench --bench trace_overhead`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::Dct2;
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    mddct::obs::set_enabled(false);
    let variant = if cfg!(feature = "trace-off") { "trace_off" } else { "default" };
    println!("\nTracing disabled-path overhead (build variant: {variant})\n");

    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[64, 256] } else { &[64, 256, 1024] };
    let mut t = Table::new(&["n", "min ms", "mean ms"]);
    let mut json_rows: Vec<String> = Vec::new();
    for &n in sizes {
        let mut rng = Rng::new(n as u64 + 9000);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];
        // serial: one thread, so every instrumented site on the solo
        // path (plan stages + FFT internals) is crossed each call
        let plan = Dct2::with_policy(n, n, ExecPolicy::Serial);
        let s = time_fn(&cfg, || {
            plan.forward(&x, &mut out);
            black_box(&out);
        });
        t.row(&[n.to_string(), ms(s.min), ms(s.mean)]);
        json_rows.push(format!(
            "{{\"n\": {n}, \"min_ms\": {:.6}, \"mean_ms\": {:.6}}}",
            s.min * 1e3,
            s.mean * 1e3
        ));
    }
    t.print();

    let path = std::env::var("MDDCT_BENCH_TRACE_JSON")
        .unwrap_or_else(|_| "BENCH_trace_overhead.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"trace_overhead\",\n  \"variant\": \"{variant}\",\n  \
         \"unit\": \"forward_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
