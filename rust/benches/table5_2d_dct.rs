//! Table V — 2D DCT/IDCT execution time and ratios:
//! direct-matmul ("MATLAB" stand-in) / row-column / fused-via-RFFT2D /
//! raw RFFT2D, plus the IDCT trio, on the paper's size grid.
//!
//! Paper shape to reproduce: fused ~2x faster than row-column at every
//! size; fused within ~1.3x of the raw RFFT2D (pre/post overhead small);
//! the library baseline an order of magnitude slower.
//!
//! Sizes: 512^2..2048^2 native (the paper's 4096/8192 rows can be enabled
//! with MDDCT_TABLE5_LARGE=1; the direct-matmul column caps at 1024 to
//! keep runtime sane). Rectangles 64x4096 / 4096x64 cover the paper's
//! 100x10000 aspect observation.
//!
//! Run: `cargo bench --bench table5_2d_dct`

use mddct::bench::{black_box, ms, ratio, time_fn, BenchConfig, Table};
use mddct::dct::direct::dct2d_direct;
use mddct::dct::{Dct2, Idct2, RowColumn};
use mddct::fft::{C64, Rfft2Plan};
use mddct::parallel::{default_threads, ExecPolicy};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    println!("\nTable V: 2D DCT/IDCT execution time in ms (ratio to fused DCT/IDCT)\n");

    let mut shapes: Vec<(usize, usize)> =
        vec![(512, 512), (1024, 1024), (2048, 2048), (64, 4096), (4096, 64)];
    if std::env::var("MDDCT_TABLE5_LARGE").is_ok() {
        shapes.push((4096, 4096));
        shapes.push((8192, 8192));
    }

    let mut t = Table::new(&[
        "N1", "N2", "matmul(MATLAB-sub)", "DCT rc", "DCT fused", "RFFT2D",
        "IDCT rc", "IDCT fused", "IRFFT2D",
    ]);
    let mut rc_ratios = Vec::new();
    let mut fft_gaps = Vec::new();
    for &(n1, n2) in &shapes {
        let mut rng = Rng::new((n1 * n2) as u64);
        let x = rng.normal_vec(n1 * n2);
        let mut out = vec![0.0; n1 * n2];

        // fused DCT (serial: Table V reproduces the paper's single-stream
        // numbers; the parallel_scaling section below measures threading)
        let dct = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
        let t_fused = time_fn(&cfg, || {
            dct.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        // row-column DCT
        let rc = RowColumn::dct2(n1, n2).with_policy(ExecPolicy::Serial);
        let t_rc = time_fn(&cfg, || {
            rc.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        // raw RFFT2D
        let rfft = Rfft2Plan::with_policy(n1, n2, ExecPolicy::Serial);
        let mut spec = vec![C64::default(); n1 * rfft.h2];
        let t_fft = time_fn(&cfg, || {
            rfft.forward(&x, &mut spec);
            black_box(&spec);
        })
        .mean;
        // direct matmul (library-baseline stand-in), capped for runtime
        let t_matmul = if n1.max(n2) <= 1024 {
            let quick = BenchConfig { iters: 3, warmup_iters: 1, ..cfg };
            Some(time_fn(&quick, || { black_box(dct2d_direct(&x, n1, n2)); }).mean)
        } else {
            None
        };
        // IDCT trio
        let idct = Idct2::with_policy(n1, n2, ExecPolicy::Serial);
        let t_ifused = time_fn(&cfg, || {
            idct.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        let irc = RowColumn::idct2(n1, n2).with_policy(ExecPolicy::Serial);
        let t_irc = time_fn(&cfg, || {
            irc.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        let mut back = vec![0.0; n1 * n2];
        let t_ifft = time_fn(&cfg, || {
            rfft.inverse(&spec, &mut back);
            black_box(&back);
        })
        .mean;

        t.row(&[
            n1.to_string(),
            n2.to_string(),
            t_matmul
                .map(|v| format!("{} {}", ms(v), ratio(v, t_fused)))
                .unwrap_or_else(|| "-".into()),
            format!("{} {}", ms(t_rc), ratio(t_rc, t_fused)),
            format!("{} (1)", ms(t_fused)),
            format!("{} {}", ms(t_fft), ratio(t_fft, t_fused)),
            format!("{} {}", ms(t_irc), ratio(t_irc, t_ifused)),
            format!("{} (1)", ms(t_ifused)),
            format!("{} {}", ms(t_ifft), ratio(t_ifft, t_ifused)),
        ]);
        rc_ratios.push(t_rc / t_fused);
        fft_gaps.push(t_fused / t_fft);
    }
    t.print();
    let mean_rc = rc_ratios.iter().sum::<f64>() / rc_ratios.len() as f64;
    let mean_gap = fft_gaps.iter().sum::<f64>() / fft_gaps.len() as f64;
    println!(
        "shape check: row-column/fused mean {:.2}x (paper ~2x); fused/RFFT2D mean \
         {:.2}x (paper ~1.2-1.3x)",
        mean_rc, mean_gap
    );

    parallel_scaling(&cfg);
}

/// Serial vs parallel fused 2D DCT (the `parallel` execution layer):
/// one row per (shape, thread count), emitted both as a table and as
/// machine-readable JSON in `BENCH_parallel.json` (override the path
/// with `MDDCT_BENCH_JSON`).
fn parallel_scaling(cfg: &BenchConfig) {
    let maxt = default_threads();
    let mut counts = vec![1usize];
    let mut c = 2;
    while c < maxt {
        counts.push(c);
        c *= 2;
    }
    if maxt > 1 {
        counts.push(maxt);
    }

    let shapes: [(usize, usize); 3] = [(512, 512), (1024, 1024), (2048, 2048)];
    println!(
        "\nParallel scaling: fused 2D DCT, serial vs 1..{maxt} threads \
         (shared pool, ExecPolicy::Threads)\n"
    );
    let mut t = Table::new(&["N1", "N2", "serial", "threads", "time", "speedup"]);
    let mut json_rows: Vec<String> = Vec::new();
    for &(n1, n2) in &shapes {
        let mut rng = Rng::new((n1 * n2) as u64 + 7);
        let x = rng.normal_vec(n1 * n2);
        let mut out = vec![0.0; n1 * n2];

        let serial_plan = Dct2::with_policy(n1, n2, ExecPolicy::Serial);
        let t_serial = time_fn(cfg, || {
            serial_plan.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;

        // correctness gate: whatever kernel the plans selected, parallel
        // output must match serial to 1e-10
        {
            let mut serial_out = vec![0.0; n1 * n2];
            serial_plan.forward(&x, &mut serial_out);
            let par_plan = Dct2::with_policy(n1, n2, ExecPolicy::Threads(maxt));
            let mut par_out = vec![0.0; n1 * n2];
            par_plan.forward(&x, &mut par_out);
            let worst = serial_out
                .iter()
                .zip(&par_out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(
                worst <= 1e-10,
                "parallel fused DCT diverged from serial: max |diff| = {worst:e} at {n1}x{n2}"
            );
        }

        for &threads in &counts {
            let plan = Dct2::with_policy(n1, n2, ExecPolicy::Threads(threads));
            let t_par = time_fn(cfg, || {
                plan.forward(&x, &mut out);
                black_box(&out);
            })
            .mean;
            let speedup = t_serial / t_par;
            t.row(&[
                n1.to_string(),
                n2.to_string(),
                ms(t_serial),
                threads.to_string(),
                ms(t_par),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(format!(
                "{{\"n1\": {n1}, \"n2\": {n2}, \"threads\": {threads}, \
                 \"serial_ms\": {:.6}, \"parallel_ms\": {:.6}, \
                 \"speedup_vs_serial\": {speedup:.4}}}",
                t_serial * 1e3,
                t_par * 1e3
            ));
        }
    }
    t.print();

    let path = std::env::var("MDDCT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_parallel.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"table5_parallel_fused_dct2d\",\n  \
         \"default_threads\": {maxt},\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
