//! 3D slab-sharding bench: the fused 3D DCT with 1 slab band vs N slab
//! bands on otherwise-identical plans (`ExecPolicy::Serial`, so the
//! shard policy alone drives the fan-out) — the volumetric analogue of
//! `benches/sharding.rs`.
//!
//! Emits a human table plus machine-readable `BENCH_volume3d.json`
//! (override the path with `MDDCT_BENCH_VOLUME3D_JSON`) so CI can track
//! the slab-scaling ratio per volume. `MDDCT_BENCH_QUICK=1` runs the
//! small volumes only.
//!
//! Run: `cargo bench --bench volume3d`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::Dct3d;
use mddct::parallel::{default_threads, ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[48, 64] } else { &[48, 64, 96, 128] };
    let nslabs = default_threads().max(2);
    println!(
        "\nSlab-sharded fused 3D DCT: 1 slab band vs {nslabs} slab bands \
         (serial exec, shard policy drives the fan-out)\n"
    );

    let slabs_hdr = format!("{nslabs} slabs ms");
    let mut t = Table::new(&["n (n^3 volume)", "1 slab ms", slabs_hdr.as_str(), "speedup"]);
    let mut json_rows: Vec<String> = Vec::new();

    for &n in sizes {
        let mut rng = Rng::new(n as u64 + 177);
        let x = rng.normal_vec(n * n * n);
        let mut out = vec![0.0; n * n * n];

        let single = Dct3d::with_policy(n, n, n, ExecPolicy::Serial)
            .with_shards(ShardPolicy::MaxShards(1));
        let one = time_fn(&cfg, || {
            single.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        // keep the 1-band output around as the correctness reference
        let want = out.clone();

        let banded = Dct3d::with_policy(n, n, n, ExecPolicy::Serial)
            .with_shards(ShardPolicy::MaxShards(nslabs));
        let many = time_fn(&cfg, || {
            banded.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;

        // sharded output must match the single-band plan to <= 1e-10
        // (relative to the output scale)
        let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let maxdiff = out
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(
            maxdiff <= 1e-10 * scale,
            "sharded dct3d diverged at n={n}: max diff {maxdiff:e}"
        );

        let speedup = one / many;
        t.row(&[
            format!("{n}^3"),
            ms(one),
            ms(many),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(format!(
            "{{\"n\": {n}, \"slabs_1_ms\": {:.6}, \"slabs_{nslabs}_ms\": {:.6}, \
             \"speedup\": {speedup:.4}}}",
            one * 1e3,
            many * 1e3
        ));
    }

    t.print();

    let path = std::env::var("MDDCT_BENCH_VOLUME3D_JSON")
        .unwrap_or_else(|_| "BENCH_volume3d.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"volume3d\",\n  \"slabs\": {nslabs},\n  \
         \"exec\": \"serial\",\n  \"unit\": \"forward_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
