//! FFT kernel-selector quick bench: old scalar radix-2 vs the
//! split-radix/radix-4 SoA kernel, single thread, on the 1D complex FFT
//! and the blocked column transform.
//!
//! Emits a human table plus machine-readable `BENCH_kernels.json`
//! (override the path with `MDDCT_BENCH_KERNELS_JSON`) so CI can track
//! the old-vs-new ratio per size. Runs quickly under
//! `MDDCT_BENCH_QUICK=1`.
//!
//! Run: `cargo bench --bench kernels`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::fft::{C64, FftKernel, FftPlan};
use mddct::util::rng::Rng;

const SIZES: [usize; 5] = [256, 512, 1024, 2048, 4096];
/// Column count for the transform_cols rows: wide enough that panel
/// blocking matters, small enough to keep CI runtime sane.
const NCOLS: usize = 256;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    println!(
        "\nFFT kernels, single thread: scalar radix-2 (old) vs split-radix/radix-4 SoA (new)\n"
    );

    let mut t = Table::new(&["op", "n", "scalar ms", "soa ms", "speedup"]);
    let mut json_rows: Vec<String> = Vec::new();

    // Timed unit is a forward+inverse roundtrip: self-restoring, so no
    // input memcpy sits inside the timed region diluting the kernel
    // ratio (the reported ms is the roundtrip, i.e. ~2 transforms).

    // ---- 1D complex FFT -----------------------------------------------
    for &n in &SIZES {
        let mut rng = Rng::new(n as u64);
        let mut data: Vec<C64> =
            (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut times = [0.0f64; 2];
        for (slot, kernel) in [FftKernel::ScalarRadix2, FftKernel::SplitRadixSoa]
            .into_iter()
            .enumerate()
        {
            let plan = FftPlan::with_kernel(n, kernel);
            times[slot] = time_fn(&cfg, || {
                plan.forward(&mut data);
                plan.inverse(&mut data);
                black_box(&data);
            })
            .mean;
        }
        push_row(&mut t, &mut json_rows, "fft1d", n, times[0], times[1]);
    }

    // ---- blocked column transform -------------------------------------
    for &n in &SIZES {
        let mut rng = Rng::new(n as u64 + 13);
        let mut data: Vec<C64> =
            (0..n * NCOLS).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut times = [0.0f64; 2];
        for (slot, kernel) in [FftKernel::ScalarRadix2, FftKernel::SplitRadixSoa]
            .into_iter()
            .enumerate()
        {
            let plan = FftPlan::with_kernel(n, kernel);
            times[slot] = time_fn(&cfg, || {
                assert!(plan.try_transform_cols(&mut data, NCOLS, false));
                assert!(plan.try_transform_cols(&mut data, NCOLS, true));
                black_box(&data);
            })
            .mean;
        }
        push_row(&mut t, &mut json_rows, "cols", n, times[0], times[1]);
    }

    t.print();

    let path = std::env::var("MDDCT_BENCH_KERNELS_JSON")
        .unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"fft_kernels\",\n  \"threads\": 1,\n  \"ncols\": {NCOLS},\n  \
         \"unit\": \"roundtrip_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn push_row(t: &mut Table, json: &mut Vec<String>, op: &str, n: usize, old: f64, new: f64) {
    let speedup = old / new;
    t.row(&[
        op.to_string(),
        n.to_string(),
        ms(old),
        ms(new),
        format!("{speedup:.2}x"),
    ]);
    json.push(format!(
        "{{\"op\": \"{op}\", \"n\": {n}, \"scalar_ms\": {:.6}, \"soa_ms\": {:.6}, \
         \"speedup\": {speedup:.4}}}",
        old * 1e3,
        new * 1e3
    ));
}
