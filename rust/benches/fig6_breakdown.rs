//! Figure 6 — runtime breakdown of the fused 2D DCT (N = 1024):
//! preprocessing vs RFFT vs postprocessing shares.
//!
//! Paper shape: RFFT dominates (~80%), pre+post together ~20%, post >
//! pre (extra arithmetic), i.e. the fused stages add little over the
//! attainable FFT floor.
//!
//! The stage numbers come from the obs span aggregation — the same
//! `dct2.pre` / `dct2.fft` / `dct2.post` spans the live service
//! breakdown is built from — so the bench and the `_stage_breakdown`
//! metrics section share one instrumentation path and cannot drift.
//! Under `--features trace-off` (spans compiled out) the bench falls
//! back to the `StageTimes` the plan returns directly; both views are
//! fed by the same `Instant` reads inside `forward_timed`.
//!
//! Emits `BENCH_fig6.json` (override with `MDDCT_BENCH_FIG6_JSON`);
//! `MDDCT_BENCH_QUICK=1` runs a CI-sized subset.
//!
//! Run: `cargo bench --bench fig6_breakdown`

use mddct::bench::{ms, time_fn, BenchConfig, Table};
use mddct::dct::{Dct2, StageTimes};
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

/// Mean per-call stage seconds (pre, fft, post) for one problem size.
fn stage_means(n: usize, cfg: &BenchConfig) -> (f64, f64, f64, usize) {
    let mut rng = Rng::new(n as u64);
    let x = rng.normal_vec(n * n);
    let mut out = vec![0.0; n * n];
    // serial: Fig. 6 is the single-thread stage breakdown
    let plan = Dct2::with_policy(n, n, ExecPolicy::Serial);
    // label this size's spans so the aggregation table keys them apart
    let _ctx = mddct::obs::with_ctx(mddct::obs::op_ctx("fig6", &[n, n]));
    let mut acc = StageTimes::default();
    let s = time_fn(cfg, || {
        let st = plan.forward_timed(&x, &mut out);
        acc.pre += st.pre;
        acc.fft += st.fft;
        acc.post += st.post;
    });
    let ctx = format!("fig6/{n}x{n}");
    let from_agg = |stage: &str| -> Option<f64> {
        let (count, total_s) = mddct::obs::stage_stats(&ctx, stage)?;
        (count > 0).then(|| total_s / count as f64)
    };
    // agg path when tracing ran; StageTimes fallback under trace-off
    let k = s.n as f64;
    let pre = from_agg("dct2.pre").unwrap_or(acc.pre / k);
    let fft = from_agg("dct2.fft").unwrap_or(acc.fft / k);
    let post = from_agg("dct2.post").unwrap_or(acc.post / k);
    (pre, fft, post, s.n)
}

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::paper());
    // the breakdown is span-sourced: turn tracing on for this process
    // (a no-op under trace-off, where the StageTimes fallback kicks in)
    mddct::obs::set_enabled(true);
    println!("\nFigure 6: runtime breakdown of the fused 2D DCT\n");

    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[512] } else { &[512, 1024, 2048] };
    let mut t = Table::new(&["N", "pre ms", "rfft ms", "post ms", "pre %", "rfft %", "post %"]);
    let mut json_rows: Vec<String> = Vec::new();
    for &n in sizes {
        mddct::obs::reset_breakdown();
        let (pre, fft, post, iters) = stage_means(n, &cfg);
        // the raw event ring is not needed here, only the aggregation;
        // drop it so long runs cannot hold tens of MB of span events
        let _ = mddct::obs::take_events();
        let total = pre + fft + post;
        t.row(&[
            n.to_string(),
            ms(pre),
            ms(fft),
            ms(post),
            format!("{:.1}%", pre / total * 100.0),
            format!("{:.1}%", fft / total * 100.0),
            format!("{:.1}%", post / total * 100.0),
        ]);
        json_rows.push(format!(
            "{{\"n\": {n}, \"iters\": {iters}, \"pre_ms\": {:.6}, \"rfft_ms\": {:.6}, \
             \"post_ms\": {:.6}, \"pre_pct\": {:.2}, \"rfft_pct\": {:.2}, \"post_pct\": {:.2}}}",
            pre * 1e3,
            fft * 1e3,
            post * 1e3,
            pre / total * 100.0,
            fft / total * 100.0,
            post / total * 100.0
        ));
        // the paper's Fig-6 ascii bar
        if n == 1024 {
            let bar = |f: f64| "#".repeat((f / total * 50.0).round() as usize);
            println!("N=1024 breakdown:");
            println!("  pre  |{}", bar(pre));
            println!("  rfft |{}", bar(fft));
            println!("  post |{}", bar(post));
            println!();
        }
    }
    t.print();
    println!("shape check: RFFT dominates; pre+post are the minority share (paper ~20%)");

    let path = std::env::var("MDDCT_BENCH_FIG6_JSON")
        .unwrap_or_else(|_| "BENCH_fig6.json".to_string());
    let source = if cfg!(feature = "trace-off") { "stage_times" } else { "span_agg" };
    let doc = format!(
        "{{\n  \"bench\": \"fig6_breakdown\",\n  \"source\": \"{source}\",\n  \
         \"unit\": \"stage_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
