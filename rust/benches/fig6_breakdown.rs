//! Figure 6 — runtime breakdown of the fused 2D DCT (N = 1024):
//! preprocessing vs RFFT vs postprocessing shares.
//!
//! Paper shape: RFFT dominates (~80%), pre+post together ~20%, post >
//! pre (extra arithmetic), i.e. the fused stages add little over the
//! attainable FFT floor.
//!
//! Run: `cargo bench --bench fig6_breakdown`

use mddct::bench::{ms, time_fn, BenchConfig, Table};
use mddct::dct::{Dct2, StageTimes};
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::paper());
    println!("\nFigure 6: runtime breakdown of the fused 2D DCT\n");

    let mut t = Table::new(&["N", "pre ms", "rfft ms", "post ms", "pre %", "rfft %", "post %"]);
    for n in [512usize, 1024, 2048] {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];
        // serial: Fig. 6 is the single-thread stage breakdown
        let plan = Dct2::with_policy(n, n, ExecPolicy::Serial);
        let mut acc = StageTimes::default();
        let s = time_fn(&cfg, || {
            let st = plan.forward_timed(&x, &mut out);
            acc.pre += st.pre;
            acc.fft += st.fft;
            acc.post += st.post;
        });
        let k = s.n as f64;
        let (pre, fft, post) = (acc.pre / k, acc.fft / k, acc.post / k);
        let total = pre + fft + post;
        t.row(&[
            n.to_string(),
            ms(pre),
            ms(fft),
            ms(post),
            format!("{:.1}%", pre / total * 100.0),
            format!("{:.1}%", fft / total * 100.0),
            format!("{:.1}%", post / total * 100.0),
        ]);
        // the paper's Fig-6 ascii bar
        if n == 1024 {
            let bar = |f: f64| "#".repeat((f / total * 50.0).round() as usize);
            println!("N=1024 breakdown:");
            println!("  pre  |{}", bar(pre));
            println!("  rfft |{}", bar(fft));
            println!("  post |{}", bar(post));
            println!();
        }
    }
    t.print();
    println!("shape check: RFFT dominates; pre+post are the minority share (paper ~20%)");
}
