//! Table VII — DREAMPlace electric potential + force step on the eight
//! ISPD-2005 designs (synthetic circuits with the published cell counts;
//! see DESIGN.md "Substitutions"), plus the §V-B IDCT_IDXST timing claim.
//!
//! Paper shape to reproduce: ours beats the row-column baseline on every
//! design (~1.7x mean), with the *end-to-end* speedup shrinking on the
//! biggest designs (Amdahl: more non-transform density/gather work), and
//! IDCT_IDXST running at plain-IDCT speed.
//!
//! Run: `cargo bench --bench table7_placement`
//! (MDDCT_TABLE7_FULL=1 uses the full published cell counts; default
//! scales cells by 1/10 to keep the bench under a minute.)

use mddct::apps::{PlacementEngine, SolverBackend, ISPD2005};
use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::{Combo, Idct2, IdxstCombo};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig { iters: 8, warmup_iters: 2, max_seconds: 60.0 });
    let scale = if std::env::var("MDDCT_TABLE7_FULL").is_ok() { 1 } else { 10 };
    println!(
        "\nTable VII: electric potential + force step (ms), baseline = row-column\n\
         (cells scaled 1/{scale}; grids as DREAMPlace derives)\n"
    );

    let mut t = Table::new(&[
        "benchmark", "cells", "grid", "baseline ms", "ours ms", "speedup",
        "e2e baseline", "e2e ours", "e2e speedup",
    ]);
    let mut speedups = Vec::new();
    let mut e2e = Vec::new();
    for b in &ISPD2005 {
        let spec = mddct::apps::IspdBenchmark {
            name: b.name,
            cells: (b.cells / scale).max(1000),
            grid: b.grid,
        };
        let mut rows: Vec<(f64, f64)> = Vec::new(); // (transform, total) per backend
        // both backends run under the default Auto exec policy, so the
        // A/B stays apples-to-apples at whatever the machine parallelism is
        for backend in [SolverBackend::RowColumn, SolverBackend::Fused] {
            let mut circuit = spec.generate(1);
            let engine = PlacementEngine::new(spec.grid, backend);
            // measure a steady-state step (plans warm)
            engine.step(&mut circuit, 0);
            let mut transform = 0.0;
            let mut total = 0.0;
            let s = time_fn(&cfg, || {
                let r = engine.step(&mut circuit, 1);
                transform += r.transform_seconds;
                total += r.transform_seconds + r.other_seconds;
                black_box(r.overflow);
            });
            let iters = s.n as f64;
            rows.push((transform / iters, total / iters));
        }
        let (base_tr, base_tot) = rows[0];
        let (ours_tr, ours_tot) = rows[1];
        t.row(&[
            b.name.to_string(),
            spec.cells.to_string(),
            format!("{}^2", spec.grid),
            ms(base_tr),
            ms(ours_tr),
            format!("{:.2}", base_tr / ours_tr),
            ms(base_tot),
            ms(ours_tot),
            format!("{:.2}", base_tot / ours_tot),
        ]);
        speedups.push(base_tr / ours_tr);
        e2e.push(base_tot / ours_tot);
    }
    t.print();
    println!(
        "transform-region speedup mean {:.2}x (paper 1.7x); end-to-end mean {:.2}x \
         — e2e < transform-only on cell-heavy designs is the paper's Amdahl effect",
        speedups.iter().sum::<f64>() / speedups.len() as f64,
        e2e.iter().sum::<f64>() / e2e.len() as f64
    );

    // §V-B claim: IDCT_IDXST times ~= plain IDCT times
    println!("\n§V-B: IDCT_IDXST vs plain IDCT (fused, ms):");
    let mut t2 = Table::new(&["N", "IDCT2D", "IDCT_IDXST", "ratio"]);
    for n in [512usize, 1024, 2048] {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];
        let idct = Idct2::new(n, n);
        let a = time_fn(&cfg, || {
            idct.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        let combo = IdxstCombo::new(n, n, Combo::IdctIdxst);
        let b = time_fn(&cfg, || {
            combo.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        t2.row(&[n.to_string(), ms(a), ms(b), format!("{:.2}", b / a)]);
    }
    t2.print();
    println!("shape check: ratio ~1.0 = \"stable performance regardless of transform type\"");
}
