//! Band-sharding bench: the fused 2D DCT with 1 shard vs N shards on
//! otherwise-identical plans (`ExecPolicy::Serial`, so the shard policy
//! alone drives the fan-out).
//!
//! Emits a human table plus machine-readable `BENCH_sharding.json`
//! (override the path with `MDDCT_BENCH_SHARDING_JSON`) so CI can track
//! the shard-scaling ratio per size. `MDDCT_BENCH_QUICK=1` runs the
//! small sizes only.
//!
//! Run: `cargo bench --bench sharding`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::Dct2;
use mddct::parallel::{default_threads, ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let sizes: &[usize] =
        if quick { &[1024, 2048] } else { &[1024, 2048, 4096, 8192] };
    let nshards = default_threads().max(2);
    println!(
        "\nBand-sharded fused 2D DCT: 1 shard vs {nshards} shards \
         (serial exec, shard policy drives the fan-out)\n"
    );

    let shards_hdr = format!("{nshards} shards ms");
    let mut t = Table::new(&["n", "1 shard ms", shards_hdr.as_str(), "speedup"]);
    let mut json_rows: Vec<String> = Vec::new();

    for &n in sizes {
        let mut rng = Rng::new(n as u64 + 77);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];

        let single = Dct2::with_policy(n, n, ExecPolicy::Serial)
            .with_shards(ShardPolicy::MaxShards(1));
        let one = time_fn(&cfg, || {
            single.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;
        // keep the 1-shard output around as the correctness reference
        let want = out.clone();

        let banded = Dct2::with_policy(n, n, ExecPolicy::Serial)
            .with_shards(ShardPolicy::MaxShards(nshards));
        let many = time_fn(&cfg, || {
            banded.forward(&x, &mut out);
            black_box(&out);
        })
        .mean;

        // sharded output must match the single-band plan to <= 1e-10
        // (relative to the output scale)
        let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let maxdiff = out
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(
            maxdiff <= 1e-10 * scale,
            "sharded dct2d diverged at n={n}: max diff {maxdiff:e}"
        );

        let speedup = one / many;
        t.row(&[
            n.to_string(),
            ms(one),
            ms(many),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(format!(
            "{{\"n\": {n}, \"shards_1_ms\": {:.6}, \"shards_{nshards}_ms\": {:.6}, \
             \"speedup\": {speedup:.4}}}",
            one * 1e3,
            many * 1e3
        ));
    }

    t.print();

    let path = std::env::var("MDDCT_BENCH_SHARDING_JSON")
        .unwrap_or_else(|_| "BENCH_sharding.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"sharding\",\n  \"shards\": {nshards},\n  \
         \"exec\": \"serial\",\n  \"unit\": \"forward_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
