//! Layout-polymorphism bench: the f32 element path and the strided
//! (zero-pack) batch path against their f64 / pack-copy baselines.
//!
//! Two sections:
//! * `elem` rows — the fused 2D DCT at f32 (`Dct2F32`) vs the same
//!   generic kernel instantiated at f64 (`GenDct2<f64>`, the
//!   apples-to-apples baseline: identical code, element width the only
//!   variable) and vs the tuned native `Dct2` f64 plan, per size. On a
//!   memory-bound transform halving the element width should buy
//!   ~1.4x+ at 1024^2 and above (`speedup_f32` = generic f64 ms / f32
//!   ms — the acceptance criterion row);
//! * `strided` rows — a batch of blocks living strided inside a padded
//!   arena: gather-pack-then-`forward_batch` (what the coordinator's
//!   packed path did for every op before layouts) vs
//!   `forward_batch_strided` running in place over the arena.
//!
//! Emits a human table plus machine-readable `BENCH_layout.json`
//! (override the path with `MDDCT_BENCH_LAYOUT_JSON`); the bench-diff
//! CI gate tracks every row. `MDDCT_BENCH_QUICK=1` runs a CI-sized
//! subset (which keeps 1024^2 — the acceptance size).
//!
//! Run: `cargo bench --bench layout`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::{Dct2, Dct2F32, GenDct2};
use mddct::layout::Layout;
use mddct::parallel::{default_threads, ExecPolicy};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    println!(
        "\nLayout polymorphism: f32 element path and strided batch execution \
         ({} pool threads under auto)\n",
        default_threads()
    );
    let mut json_rows: Vec<String> = Vec::new();

    // ---- elem rows: f32 vs f64 on the same generic kernel ------------
    let sizes: &[usize] = if quick { &[256, 1024] } else { &[256, 512, 1024, 2048] };
    let mut te = Table::new(&["n", "native f64 ms", "gen f64 ms", "f32 ms", "f32 speedup"]);
    for &n in sizes {
        let mut rng = Rng::new(n as u64 + 7000);
        let x = rng.normal_vec(n * n);
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();

        let native = Dct2::with_policy(n, n, ExecPolicy::Serial);
        let mut y = vec![0.0; n * n];
        let native_ms = time_fn(&cfg, || {
            native.forward(&x, &mut y);
            black_box(&y);
        })
        .mean;

        let gen64: GenDct2<f64> = GenDct2::new(n, n);
        let mut y64 = vec![0.0; n * n];
        let gen64_ms = time_fn(&cfg, || {
            gen64.forward(&x, &mut y64);
            black_box(&y64);
        })
        .mean;

        let gen32 = Dct2F32::new(n, n);
        let mut y32 = vec![0.0f32; n * n];
        // correctness gate before timing: f32 tracks the f64 result
        gen32.forward(&x32, &mut y32);
        let scale = y.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (g, w) in y32.iter().zip(&y) {
            assert!(
                (f64::from(*g) - w).abs() <= 1e-3 * scale,
                "f32 diverged at n={n}: {g} vs {w}"
            );
        }
        let f32_ms = time_fn(&cfg, || {
            gen32.forward(&x32, &mut y32);
            black_box(&y32);
        })
        .mean;

        let speedup = gen64_ms / f32_ms;
        te.row(&[
            n.to_string(),
            ms(native_ms),
            ms(gen64_ms),
            ms(f32_ms),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(format!(
            "{{\"section\": \"elem\", \"n\": {n}, \"native_f64_ms\": {:.6}, \
             \"gen_f64_ms\": {:.6}, \"f32_ms\": {:.6}, \"speedup_f32\": {speedup:.4}}}",
            native_ms * 1e3,
            gen64_ms * 1e3,
            f32_ms * 1e3
        ));
    }
    te.print();

    // ---- strided rows: gather-pack vs in-place strided batch ---------
    let cases: &[(usize, usize)] = if quick { &[(16, 256)] } else { &[(16, 256), (32, 256), (64, 64)] };
    let mut ts = Table::new(&["n", "batch", "pack ms", "strided ms", "speedup"]);
    for &(n, batch) in cases {
        let numel = n * n;
        // blocks tiled along the row axis of one padded arena row-block,
        // 2x horizontal padding between columns of each block
        let (s2, s1) = (2usize, 2 * n + 3);
        let span = (n - 1) * s1 + (n - 1) * s2 + 1;
        let bstride = span + 5;
        let layout = Layout::contiguous(&[n, n])
            .with_strides(&[s1, s2])
            .with_batch_stride(bstride);
        let mut rng = Rng::new((n * 31 + batch) as u64);
        let arena = rng.normal_vec(layout.required_len(batch));
        let plan = Dct2::with_policy(n, n, ExecPolicy::Auto);
        let mut out = vec![0.0; numel * batch];

        // the pre-layout behaviour: gather every block into a pack
        // buffer, then run the packed batch
        let mut packed = vec![0.0; numel * batch];
        let gather_pack = |packed: &mut [f64]| {
            for b in 0..batch {
                let base = b * bstride;
                for i in 0..n {
                    for j in 0..n {
                        packed[b * numel + i * n + j] = arena[base + i * s1 + j * s2];
                    }
                }
            }
        };

        // correctness gate: strided == gather-then-pack, bitwise
        gather_pack(&mut packed);
        let mut want = vec![0.0; numel * batch];
        plan.forward_batch(&packed, &mut want, batch);
        plan.forward_batch_strided(&arena, &layout, &mut out, batch);
        assert_eq!(out, want, "strided batch diverged at n={n} batch={batch}");

        let pack_ms = time_fn(&cfg, || {
            gather_pack(&mut packed);
            plan.forward_batch(&packed, &mut out, batch);
            black_box(&out);
        })
        .mean;
        let strided_ms = time_fn(&cfg, || {
            plan.forward_batch_strided(&arena, &layout, &mut out, batch);
            black_box(&out);
        })
        .mean;
        let speedup = pack_ms / strided_ms;
        ts.row(&[
            n.to_string(),
            batch.to_string(),
            ms(pack_ms),
            ms(strided_ms),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(format!(
            "{{\"section\": \"strided\", \"n\": {n}, \"batch\": {batch}, \
             \"pack_ms\": {:.6}, \"strided_ms\": {:.6}, \"speedup\": {speedup:.4}}}",
            pack_ms * 1e3,
            strided_ms * 1e3
        ));
    }
    println!("\nStrided batch: gather-pack + forward_batch vs forward_batch_strided in place\n");
    ts.print();

    let path = std::env::var("MDDCT_BENCH_LAYOUT_JSON")
        .unwrap_or_else(|_| "BENCH_layout.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"layout\",\n  \"threads\": {},\n  \"unit\": \"forward_ms\",\n  \
         \"rows\": [\n    {}\n  ]\n}}\n",
        default_threads(),
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
