//! Noisy-neighbor isolation bench for the per-tenant admission budget.
//!
//! Two tenants share one in-process [`Service`] with a deliberately
//! tight in-flight budget. Tenant B is the well-behaved victim: it
//! offers 0.25x the measured closed-loop capacity, first alone (the
//! solo baseline) and then while tenant A — the noisy neighbor —
//! offers 4x capacity on the same service. The weighted fair share
//! (`MDDCT_TENANT_QUOTA`, equal weights here) must keep admitting B
//! while A's over-share traffic is shed, and B's higher priority must
//! keep its admitted requests at the front of the batcher drain: the
//! acceptance bar is B's contended p99 within 2x of its solo p99.
//!
//! Latency is service-side (`Response::latency`: queue + execute), so
//! the numbers isolate scheduling, not client pacing. Emits a human
//! table and machine-readable `BENCH_tenants.json` (override with
//! `MDDCT_BENCH_TENANTS_JSON`); the bench-diff CI gate tracks the
//! `*_ms` columns per row, while shed ratios and the isolation ratio
//! ride in ungated `speedup_*` fields. `MDDCT_BENCH_QUICK=1` runs a
//! CI-sized subset.
//!
//! Run: `cargo bench --bench tenants`

use std::sync::Arc;
use std::time::{Duration, Instant};

use mddct::bench::{ms, Table};
use mddct::coordinator::{
    BatchPolicy, Service, ServiceConfig, SubmitOptions, TransformError, TransformOp,
};
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

/// Fixed worker count: part of each row's identity, so it must not
/// float with the runner's core count.
const WORKERS: usize = 2;
/// One 64x64 block per request — large enough that service time (not
/// submit overhead) dominates the closed-loop calibration.
const N1: usize = 64;
const N2: usize = 64;
/// In-flight budget: four blocks. Tight on purpose — the noisy
/// neighbor must hit the budget, so isolation (not slack) is what
/// keeps the victim's tail flat.
const MAX_INFLIGHT: usize = N1 * N2 * 4;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// Open-loop submitter for one tenant: submit `n` blocks at a fixed
/// interarrival (sleep-until-due with catch-up, so the *average* rate
/// holds even when a sleep overshoots), then wait every handle.
/// Returns (service-side latencies, shed count).
fn run_tenant(
    svc: Arc<Service>,
    tenant: &'static str,
    priority: u8,
    n: usize,
    interarrival: Duration,
) -> (Vec<f64>, usize) {
    let mut rng = Rng::new(0xBEEF ^ tenant.len() as u64);
    let data = rng.normal_vec(N1 * N2);
    let start = Instant::now();
    let mut handles = Vec::with_capacity(n);
    let mut shed = 0usize;
    for i in 0..n {
        let due = start + interarrival * (i as u32);
        let now = Instant::now();
        if now < due {
            std::thread::sleep(due - now);
        }
        let opts = SubmitOptions { deadline: None, tenant: Some(tenant.to_string()), priority };
        match svc.submit_opts(TransformOp::Dct2d, vec![N1, N2], data.clone(), opts) {
            Ok(h) => handles.push(h),
            Err(TransformError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("{tenant}: unexpected submit error: {e}"),
        }
    }
    let mut lats = Vec::with_capacity(handles.len());
    for h in handles {
        match h.wait() {
            Ok(resp) => lats.push(resp.latency),
            Err(TransformError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("{tenant}: unexpected reply error: {e}"),
        }
    }
    (lats, shed)
}

struct Phase {
    scenario: &'static str,
    tenant: &'static str,
    offered: f64,
    ok: usize,
    total: usize,
    shed: usize,
    p50: f64,
    p99: f64,
}

fn phase_row(
    scenario: &'static str,
    tenant: &'static str,
    offered: f64,
    n: usize,
    lats: &mut [f64],
    shed: usize,
) -> Phase {
    lats.sort_by(|a, b| a.total_cmp(b));
    Phase {
        scenario,
        tenant,
        offered,
        ok: lats.len(),
        total: n,
        shed,
        p50: percentile(lats, 0.50),
        p99: percentile(lats, 0.99),
    }
}

fn main() {
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let (mode, n_b) = if quick { ("quick", 200usize) } else { ("full", 1000usize) };
    // equal fair shares, stated explicitly so the bench exercises the
    // quota-spec path end to end (unlisted tenants would weigh 1.0
    // anyway); must be set before the service constructs its budget
    std::env::set_var("MDDCT_TENANT_QUOTA", "tenant-a:1,tenant-b:1");

    let svc = Arc::new(Service::start_native(ServiceConfig {
        workers: WORKERS,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: MAX_INFLIGHT,
    }));

    // closed-loop calibration (plans warm): capacity of the pool
    let mut rng = Rng::new(7);
    for _ in 0..8 {
        svc.transform(TransformOp::Dct2d, vec![N1, N2], rng.normal_vec(N1 * N2)).expect("warmup");
    }
    let cal = 32;
    let t0 = Instant::now();
    for _ in 0..cal {
        let data = rng.normal_vec(N1 * N2);
        svc.transform(TransformOp::Dct2d, vec![N1, N2], data).expect("calibrate");
    }
    let svc_s = t0.elapsed().as_secs_f64() / cal as f64;
    let capacity = WORKERS as f64 / svc_s;
    println!(
        "\nNoisy-neighbor isolation: {WORKERS} workers, {N1}x{N2} blocks, budget {} blocks, \
         closed-loop service time {} => capacity ~{capacity:.0} req/s\n",
        MAX_INFLIGHT / (N1 * N2),
        ms(svc_s)
    );

    let rate_b = 0.25 * capacity;
    let rate_a = 4.0 * capacity;
    let ia_b = Duration::from_secs_f64(1.0 / rate_b);
    let ia_a = Duration::from_secs_f64(1.0 / rate_a);
    // A covers B's wall-clock window at 16x B's rate
    let n_a = n_b * 16;

    // phase 1 — solo baseline: the victim alone at 0.25x capacity
    let (mut b_solo, b_solo_shed) = run_tenant(svc.clone(), "tenant-b", 1, n_b, ia_b);
    let solo = phase_row("solo", "tenant-b", rate_b, n_b, &mut b_solo, b_solo_shed);

    // phase 2 — contended: the same victim stream while the noisy
    // neighbor offers 4x capacity (priority 0 vs the victim's 1)
    let svc_a = svc.clone();
    let noisy = std::thread::spawn(move || run_tenant(svc_a, "tenant-a", 0, n_a, ia_a));
    let (mut b_cont, b_cont_shed) = run_tenant(svc.clone(), "tenant-b", 1, n_b, ia_b);
    let (mut a_cont, a_cont_shed) = noisy.join().expect("noisy-neighbor thread");
    let cont_a = phase_row("contended", "tenant-a", rate_a, n_a, &mut a_cont, a_cont_shed);
    let cont_b = phase_row("contended", "tenant-b", rate_b, n_b, &mut b_cont, b_cont_shed);

    let ratio = cont_b.p99 / solo.p99.max(1e-9);
    let mut t = Table::new(&["scenario", "tenant", "offered req/s", "ok", "shed", "p50", "p99"]);
    let mut json_rows: Vec<String> = Vec::new();
    for ph in [&solo, &cont_a, &cont_b] {
        let shed_ratio = ph.shed as f64 / ph.total as f64;
        t.row(&[
            ph.scenario.to_string(),
            ph.tenant.to_string(),
            format!("{:.0}", ph.offered),
            format!("{}/{}", ph.ok, ph.total),
            format!("{} ({:.1}%)", ph.shed, 100.0 * shed_ratio),
            ms(ph.p50),
            ms(ph.p99),
        ]);
        json_rows.push(format!(
            "{{\"section\": \"tenants\", \"mode\": \"{mode}\", \"workers\": {WORKERS}, \
             \"scenario\": \"{}\", \"tenant\": \"{}\", \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \
             \"speedup_shed_ratio\": {shed_ratio:.4}}}",
            ph.scenario,
            ph.tenant,
            ph.p50 * 1e3,
            ph.p99 * 1e3
        ));
    }
    t.print();
    println!(
        "\nisolation: victim p99 {} solo -> {} contended ({ratio:.2}x; acceptance bar 2x)",
        ms(solo.p99),
        ms(cont_b.p99)
    );
    if ratio > 2.0 {
        eprintln!("WARNING: tenant-b contended p99 is {ratio:.2}x solo (> 2x isolation bar)");
    }
    // the isolation ratio is a cross-row quantity: its own row, with no
    // gated *_ms fields, so runner noise cannot redden the trend gate
    json_rows.push(format!(
        "{{\"section\": \"tenants\", \"mode\": \"{mode}\", \"workers\": {WORKERS}, \
         \"scenario\": \"isolation\", \"tenant\": \"tenant-b\", \
         \"speedup_b_p99_ratio\": {ratio:.4}}}"
    ));
    println!("\nfinal snapshot: {}", svc.snapshot());

    let path = std::env::var("MDDCT_BENCH_TENANTS_JSON")
        .unwrap_or_else(|_| "BENCH_tenants.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"tenants\",\n  \"unit\": \"latency_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
