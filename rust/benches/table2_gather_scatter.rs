//! Table II — 2D DCT preprocessing time with gather vs scatter.
//!
//! Paper: N = 512..8192 on a Titan Xp; gather (coalesced writes) and
//! scatter (coalesced reads) perform the same. Here the CPU analogue is
//! sequential-write vs sequential-read loop order; the reproduced claim
//! is that the two orders are equivalent, so the library's choice of
//! scatter is free.
//!
//! Run: `cargo bench --bench table2_gather_scatter`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::reorder::{reorder_2d_gather, reorder_2d_scatter};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    println!("\nTable II: 2D DCT preprocessing time (ms), gather vs scatter");
    println!("(paper: 0.013..2.57 ms on Titan Xp; claim = the two are equal)\n");

    let sizes = [512usize, 1024, 2048, 4096, 8192];
    let mut gather_row = vec!["Gather".to_string()];
    let mut scatter_row = vec!["Scatter".to_string()];
    let mut ratio_row = vec!["Gather/Scatter".to_string()];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n * n);
        let mut out = vec![0.0; n * n];
        let g = time_fn(&cfg, || {
            reorder_2d_gather(&x, &mut out, n, n);
            black_box(&out);
        });
        let s = time_fn(&cfg, || {
            reorder_2d_scatter(&x, &mut out, n, n);
            black_box(&out);
        });
        gather_row.push(ms(g.mean));
        scatter_row.push(ms(s.mean));
        ratio_row.push(format!("{:.2}", g.mean / s.mean));
    }

    let headers: Vec<String> =
        std::iter::once("N".to_string()).chain(sizes.iter().map(|n| n.to_string())).collect();
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    t.row(&gather_row);
    t.row(&scatter_row);
    t.row(&ratio_row);
    t.print();
    println!("shape check: ratios ~1.0 reproduce the paper's \"similar performance\" claim");
}
