//! Open-loop wire-level load generator for the TCP front-end.
//!
//! Drives `mddct serve` end to end — frame encode, socket, per-conn
//! reader thread, service submit, reply encode — with a mixed-shape
//! request stream (pow2 and Bluestein 2D blocks plus a fused combo)
//! over several pipelined connections. Arrival is open-loop at 0.5x /
//! 1x / 2x the measured closed-loop capacity, so above capacity the
//! admission budget must shed and the shed requests come back as typed
//! `overloaded` error frames, not stalls.
//!
//! Reports wall latency (send to reply receipt) p50 / p99 / p999 per
//! load, plus the admit ratio. Emits a human table and
//! machine-readable `BENCH_service.json` (override the path with
//! `MDDCT_BENCH_SERVICE_JSON`); the bench-diff CI gate tracks the
//! `*_ms` columns per row (`speedup_`-prefixed fields are reported but
//! not gated). `MDDCT_BENCH_QUICK=1` runs a CI-sized subset.
//!
//! Run: `cargo bench --bench service`

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mddct::bench::{ms, Table};
use mddct::coordinator::{BatchPolicy, Service, ServiceConfig, TransformOp};
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::server::proto::{self, WireReply, WireRequest};
use mddct::server::{Server, ServerConfig};
use mddct::util::rng::Rng;

/// Fixed worker count: part of each row's identity, so it must not
/// float with the runner's core count.
const WORKERS: usize = 2;
/// Pipelined client connections.
const CONNS: usize = 4;
/// Admission cap: deep enough to absorb bursts at capacity, shallow
/// enough that 2x offered load sheds rather than queues.
const MAX_INFLIGHT: usize = 64 * 32 * 32;

/// The request mix: pow2 and Bluestein 2D blocks plus a fused combo.
fn request_mix() -> Vec<(TransformOp, Vec<usize>)> {
    vec![
        (TransformOp::Dct2d, vec![32, 32]),
        (TransformOp::Idct2d, vec![24, 24]),
        (TransformOp::IdctIdxst, vec![16, 16]),
        (TransformOp::Dct2d, vec![27, 15]),
    ]
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// One pipelined connection: a writer thread holds the open-loop
/// schedule while this thread reads replies in order (the server
/// answers each connection's frames FIFO), pairing each reply with its
/// send instant. Returns (wall latencies, shed count).
fn run_conn(
    addr: SocketAddr,
    templates: Arc<Vec<String>>,
    n: usize,
    interarrival: Duration,
    start: Instant,
) -> (Vec<f64>, usize) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut rd = stream.try_clone().expect("clone stream");
    let sends: Arc<Mutex<VecDeque<Instant>>> = Arc::new(Mutex::new(VecDeque::new()));
    let sends_w = sends.clone();
    let writer = std::thread::spawn(move || {
        let mut wr = stream;
        for i in 0..n {
            let due = start + interarrival * (i as u32);
            while Instant::now() < due {
                std::hint::spin_loop();
            }
            let body = &templates[i % templates.len()];
            sends_w.lock().unwrap().push_back(Instant::now());
            proto::write_frame(&mut wr, body.as_bytes()).expect("write frame");
        }
    });
    let mut lats = Vec::with_capacity(n);
    let mut shed = 0usize;
    for _ in 0..n {
        let body = proto::read_frame(&mut rd, proto::DEFAULT_MAX_FRAME_BYTES)
            .expect("read frame")
            .expect("reply before EOF");
        let received = Instant::now();
        let sent = sends.lock().unwrap().pop_front().expect("send instant");
        match proto::decode_reply(&body).expect("decode reply") {
            WireReply::Ok { .. } => lats.push((received - sent).as_secs_f64()),
            WireReply::Err { .. } => shed += 1,
            WireReply::Metrics(_) => {}
        }
    }
    writer.join().expect("writer thread");
    (lats, shed)
}

fn main() {
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let (mode, per_conn) = if quick { ("quick", 250usize) } else { ("full", 2000usize) };

    let svc = Arc::new(Service::start_native(ServiceConfig {
        workers: WORKERS,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: MAX_INFLIGHT,
    }));
    let server = Server::start(ServerConfig::ephemeral(), svc.clone()).expect("start server");
    let addr = server.addr();

    // pre-encode one request body per mix entry; clients cycle through
    let mut rng = Rng::new(42);
    let mix = request_mix();
    let templates: Vec<String> = mix
        .iter()
        .map(|(op, shape)| {
            let numel: usize = shape.iter().product();
            proto::encode_request(&WireRequest {
                id: 0,
                op: *op,
                shape: shape.clone(),
                batch: 1,
                deadline_ms: None,
                tenant: None,
                priority: 0,
                data: rng.normal_vec(numel),
            })
        })
        .collect();
    let templates = Arc::new(templates);

    // closed-loop calibration over the same mix (plans warm); offered
    // rates are multiples of the implied pool capacity
    for (op, shape) in &mix {
        let numel: usize = shape.iter().product();
        for _ in 0..4 {
            svc.transform(*op, shape.clone(), rng.normal_vec(numel)).expect("warmup");
        }
    }
    let cal = 32;
    let t0 = Instant::now();
    for i in 0..cal {
        let (op, shape) = &mix[i % mix.len()];
        let numel: usize = shape.iter().product();
        svc.transform(*op, shape.clone(), rng.normal_vec(numel)).expect("calibrate");
    }
    let svc_s = t0.elapsed().as_secs_f64() / cal as f64;
    let capacity = WORKERS as f64 / svc_s;
    println!(
        "\nWire-level open loop: {CONNS} conns, {WORKERS} workers, {} shapes mixed, \
         closed-loop service time {} => capacity ~{capacity:.0} req/s\n",
        mix.len(),
        ms(svc_s)
    );

    let mut t = Table::new(&["load", "offered req/s", "ok", "shed", "p50", "p99", "p999"]);
    let mut json_rows: Vec<String> = Vec::new();
    for (label, mult) in [("0.5x", 0.5f64), ("1x", 1.0), ("2x", 2.0)] {
        let interarrival = Duration::from_secs_f64(CONNS as f64 / (capacity * mult));
        let start = Instant::now();
        let conns: Vec<_> = (0..CONNS)
            .map(|_| {
                let templates = templates.clone();
                std::thread::spawn(move || run_conn(addr, templates, per_conn, interarrival, start))
            })
            .collect();
        let mut lats: Vec<f64> = Vec::new();
        let mut shed = 0usize;
        for c in conns {
            let (mut l, s) = c.join().expect("conn thread");
            lats.append(&mut l);
            shed += s;
        }
        let elapsed = start.elapsed().as_secs_f64();
        let total = CONNS * per_conn;
        let ok = lats.len();
        lats.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&lats, 0.50);
        let p99 = percentile(&lats, 0.99);
        let p999 = percentile(&lats, 0.999);
        let per_req_ms = 1e3 * elapsed / ok.max(1) as f64;
        let admit_ratio = ok as f64 / total as f64;
        t.row(&[
            label.to_string(),
            format!("{:.0}", capacity * mult),
            format!("{ok}/{total}"),
            format!("{shed} ({:.1}%)", 100.0 * shed as f64 / total as f64),
            ms(p50),
            ms(p99),
            ms(p999),
        ]);
        json_rows.push(format!(
            "{{\"section\": \"service\", \"mode\": \"{mode}\", \"conns\": {CONNS}, \
             \"workers\": {WORKERS}, \"load\": \"{label}\", \
             \"per_req_ms\": {per_req_ms:.6}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \
             \"p999_ms\": {:.6}, \"speedup_admit_ratio\": {admit_ratio:.4}}}",
            p50 * 1e3,
            p99 * 1e3,
            p999 * 1e3
        ));
    }
    t.print();
    println!(
        "\nfinal snapshot: {}",
        svc.snapshot_with(&[("_server", server.stats().snapshot())])
    );

    let path = std::env::var("MDDCT_BENCH_SERVICE_JSON")
        .unwrap_or_else(|_| "BENCH_service.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"service\",\n  \"unit\": \"latency_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
