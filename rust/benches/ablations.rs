//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A. packed even-N RFFT vs full complex FFT        (fft::rfft)
//!   B. vectorized column FFT vs strided per-column   (§Perf iter. 2)
//!   C. thread-local scratch pool vs fresh allocation (§Perf iter. 1)
//!   D. DST via DCT-fold vs direct O(N^2) evaluation  (§III-D extension)
//!
//! Run: `cargo bench --bench ablations`

use mddct::bench::{black_box, ms, time_fn, BenchConfig, Table};
use mddct::dct::dst::{dst2d_direct, Dst2};
use mddct::dct::Dct2;
use mddct::parallel::ExecPolicy;
use mddct::fft::radix2::Radix2Plan;
use mddct::fft::{onesided_len, plan, C64, RfftPlan};
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());

    // ---- A: packed RFFT vs full complex FFT ---------------------------
    println!("\nAblation A: even-N packed RFFT vs full complex FFT of real input");
    let mut t = Table::new(&["N", "packed rfft ms", "full cfft ms", "speedup"]);
    for n in [1 << 14, 1 << 16, 1 << 18] {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec(n);
        let rp = RfftPlan::new(n);
        let mut spec = vec![C64::default(); onesided_len(n)];
        let packed = time_fn(&cfg, || {
            rp.forward(&x, &mut spec);
            black_box(&spec);
        })
        .mean;
        let fp = plan(n);
        let full = time_fn(&cfg, || {
            let mut buf: Vec<C64> = x.iter().map(|&r| C64::new(r, 0.0)).collect();
            fp.forward(&mut buf);
            black_box(&buf);
        })
        .mean;
        t.row(&[n.to_string(), ms(packed), ms(full), format!("{:.2}x", full / packed)]);
    }
    t.print();

    // ---- B: vectorized vs strided column FFT --------------------------
    println!("\nAblation B: column FFT, vectorized whole-row butterflies vs strided gather");
    let mut t = Table::new(&["n1 x ncols", "vectorized ms", "strided ms", "speedup"]);
    for (n1, ncols) in [(1024usize, 513usize), (2048, 1025)] {
        let mut rng = Rng::new((n1 * ncols) as u64);
        let base: Vec<C64> =
            (0..n1 * ncols).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let p = Radix2Plan::new(n1);
        let mut data = base.clone();
        let vec_t = time_fn(&cfg, || {
            data.copy_from_slice(&base);
            p.transform_cols(&mut data, ncols, false);
            black_box(&data);
        })
        .mean;
        let strided = time_fn(&cfg, || {
            data.copy_from_slice(&base);
            let mut colbuf = vec![C64::default(); n1];
            for c in 0..ncols {
                for r in 0..n1 {
                    colbuf[r] = data[r * ncols + c];
                }
                p.forward(&mut colbuf);
                for r in 0..n1 {
                    data[r * ncols + c] = colbuf[r];
                }
            }
            black_box(&data);
        })
        .mean;
        t.row(&[
            format!("{n1} x {ncols}"),
            ms(vec_t),
            ms(strided),
            format!("{:.2}x", strided / vec_t),
        ]);
    }
    t.print();

    // ---- C: scratch pool vs fresh allocation --------------------------
    println!("\nAblation C: fused DCT with scratch pool (current) vs fresh-allocation cost model");
    let n = 1024;
    let mut rng = Rng::new(77);
    let x = rng.normal_vec(n * n);
    let mut out = vec![0.0; n * n];
    // serial: §Perf iteration 1 measured the single-thread allocation cost
    let dct = Dct2::with_policy(n, n, ExecPolicy::Serial);
    let pooled = time_fn(&cfg, || {
        dct.forward(&x, &mut out);
        black_box(&out);
    })
    .mean;
    // model the old behaviour: same transform + the two buffer
    // allocations and first-touch passes it used to pay
    let alloc = time_fn(&cfg, || {
        let pre = vec![0.0f64; n * n];
        let spec = vec![C64::default(); n * (n / 2 + 1)];
        black_box((&pre, &spec));
        dct.forward(&x, &mut out);
        black_box(&out);
    })
    .mean;
    println!(
        "  pooled {:.2} ms vs +fresh-alloc {:.2} ms  ({:.2}x) — §Perf iteration 1",
        pooled * 1e3,
        alloc * 1e3,
        alloc / pooled
    );

    // ---- D: DST via fold vs direct ------------------------------------
    println!("\nAblation D: 2D DST via DCT fold vs direct O(N^2.N) evaluation");
    let n = 128;
    let x = rng.normal_vec(n * n);
    let mut y = vec![0.0; n * n];
    let dst = Dst2::new(n, n);
    let fold = time_fn(&cfg, || {
        dst.forward(&x, &mut y);
        black_box(&y);
    })
    .mean;
    let quick = BenchConfig { iters: 3, warmup_iters: 1, ..cfg };
    let direct = time_fn(&quick, || {
        black_box(dst2d_direct(&x, n, n));
    })
    .mean;
    println!(
        "  fold {:.3} ms vs direct {:.1} ms  ({:.0}x) — the paradigm covers the DST family",
        fold * 1e3,
        direct * 1e3,
        direct / fold
    );
}
