//! Open-loop overload bench: offered load vs goodput, shed rate, and
//! tail latency through the full service lifecycle (admission budget,
//! batcher, workers).
//!
//! Closed-loop benches (submit, wait, repeat) can never overload the
//! service — arrival pauses whenever the pool stalls. This bench is
//! open-loop: requests arrive on a fixed schedule derived from the
//! service's measured closed-loop capacity, at 1x / 2x / 4x that rate,
//! whether or not earlier requests have finished. Above capacity the
//! bounded inflight budget must shed (`Overloaded`) rather than grow
//! the queue, and the tail latency of the admitted requests stays
//! bounded by queue depth — both show up as trend-gated numbers here.
//!
//! Emits a human table plus machine-readable `BENCH_overload.json`
//! (override the path with `MDDCT_BENCH_OVERLOAD_JSON`); the bench-diff
//! CI gate tracks the `*_ms` columns per row (`speedup_`-prefixed
//! fields are reported but not gated, per the bench_diff convention —
//! the admit ratio is one, since it is load-derived, not a time).
//! `MDDCT_BENCH_QUICK=1` runs a CI-sized subset.
//!
//! Run: `cargo bench --bench overload`

use std::time::{Duration, Instant};

use mddct::bench::{ms, Table};
use mddct::coordinator::{BatchPolicy, Service, ServiceConfig, TransformOp};
use mddct::parallel::{ExecPolicy, ShardPolicy};
use mddct::util::rng::Rng;

/// Block edge: large enough that service time dwarfs channel hops,
/// small enough that requests co-batch rather than shard.
const N: usize = 64;
/// Fixed worker count: part of each row's identity, so it must not
/// float with the runner's core count.
const WORKERS: usize = 2;
/// Admission cap: 16 in-flight payloads — deep enough to absorb
/// bursts at capacity, shallow enough that 4x offered load sheds.
const MAX_INFLIGHT: usize = 16 * N * N;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

fn main() {
    let quick = std::env::var("MDDCT_BENCH_QUICK").is_ok();
    let (mode, requests) = if quick { ("quick", 400usize) } else { ("full", 4000usize) };

    let svc = Service::start_native(ServiceConfig {
        workers: WORKERS,
        batch: BatchPolicy::default(),
        exec: ExecPolicy::Serial,
        shard: ShardPolicy::Auto,
        trace: false,
        default_deadline: None,
        max_inflight_elems: MAX_INFLIGHT,
    });
    let mut rng = Rng::new(42);
    let payload = rng.normal_vec(N * N);

    // measure closed-loop service time (plan warm, one request at a
    // time); offered rates are multiples of the implied pool capacity
    for _ in 0..8 {
        svc.transform(TransformOp::Dct2d, vec![N, N], payload.clone()).unwrap();
    }
    let cal = 64;
    let t0 = Instant::now();
    for _ in 0..cal {
        svc.transform(TransformOp::Dct2d, vec![N, N], payload.clone()).unwrap();
    }
    let svc_s = t0.elapsed().as_secs_f64() / cal as f64;
    let capacity = WORKERS as f64 / svc_s;
    println!(
        "\nOpen-loop overload: dct2d {N}x{N}, {WORKERS} workers, budget {MAX_INFLIGHT} elems, \
         closed-loop service time {} => capacity ~{capacity:.0} req/s\n",
        ms(svc_s)
    );

    let mut t = Table::new(&["load", "offered req/s", "goodput req/s", "shed", "p50", "p99"]);
    let mut json_rows: Vec<String> = Vec::new();
    for (label, mult) in [("1x", 1.0f64), ("2x", 2.0), ("4x", 4.0)] {
        let interarrival = Duration::from_secs_f64(1.0 / (capacity * mult));
        let start = Instant::now();
        let mut handles = Vec::with_capacity(requests);
        let mut shed = 0usize;
        for i in 0..requests {
            // open loop: hold the schedule even when the pool is behind
            let due = start + interarrival * (i as u32);
            while Instant::now() < due {
                std::hint::spin_loop();
            }
            match svc.submit(TransformOp::Dct2d, vec![N, N], payload.clone()) {
                Ok(h) => handles.push(h),
                Err(e) if e.is_retryable() => shed += 1,
                Err(e) => panic!("submit failed: {e}"),
            }
        }
        let mut lats: Vec<f64> =
            handles.into_iter().filter_map(|h| h.wait().ok()).map(|r| r.latency).collect();
        let elapsed = start.elapsed().as_secs_f64();
        let ok = lats.len();
        lats.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&lats, 0.50);
        let p99 = percentile(&lats, 0.99);
        let goodput = ok as f64 / elapsed;
        let per_req_ms = 1e3 * elapsed / ok.max(1) as f64;
        let admit_ratio = ok as f64 / requests as f64;
        t.row(&[
            label.to_string(),
            format!("{:.0}", capacity * mult),
            format!("{goodput:.0}"),
            format!("{shed} ({:.1}%)", 100.0 * shed as f64 / requests as f64),
            ms(p50),
            ms(p99),
        ]);
        json_rows.push(format!(
            "{{\"section\": \"overload\", \"mode\": \"{mode}\", \"n\": {N}, \
             \"workers\": {WORKERS}, \"load\": \"{label}\", \
             \"per_req_ms\": {per_req_ms:.6}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \
             \"speedup_admit_ratio\": {admit_ratio:.4}}}",
            p50 * 1e3,
            p99 * 1e3
        ));
    }
    t.print();
    println!("\nfinal snapshot: {}", svc.snapshot());

    let path = std::env::var("MDDCT_BENCH_OVERLOAD_JSON")
        .unwrap_or_else(|_| "BENCH_overload.json".to_string());
    let doc = format!(
        "{{\n  \"bench\": \"overload\",\n  \"unit\": \"latency_ms\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        json_rows.join(",\n    ")
    );
    match std::fs::write(&path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
