//! Table VI — kernel utilization (GPU counters -> CPU roofline substitute).
//!
//! The paper's nvprof table argues one thing: both kernels are
//! memory-bound and run near peak bandwidth. Here we (1) measure the
//! machine's practical copy/triad bandwidth, (2) time the pre/post
//! kernels, (3) report achieved bandwidth as a fraction of the roofline
//! (the Mem.BW column analogue). Occupancy/SM columns have no CPU
//! analogue and are reported as the bytes-moved model instead.
//!
//! Run: `cargo bench --bench table6_utilization`

use mddct::bench::roofline::{
    achieved_fraction, measure_machine, postprocess_traffic, preprocess_traffic,
};
use mddct::bench::{black_box, time_fn, BenchConfig, Table};
use mddct::dct::reorder::reorder_2d_scatter;
use mddct::dct::Dct2;
use mddct::fft::{onesided_len, C64};
use mddct::parallel::ExecPolicy;
use mddct::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let n = 1024usize;
    println!("\nTable VI substitute: kernel bandwidth utilization ({}x{} f64)\n", n, n);

    let machine = measure_machine(1 << 22, 5);
    println!(
        "machine roofline: copy {:.2} GB/s, triad {:.2} GB/s (single thread)",
        machine.copy_bw / 1e9,
        machine.triad_bw / 1e9
    );

    let mut rng = Rng::new(6);
    let x = rng.normal_vec(n * n);
    let mut out = vec![0.0; n * n];
    let t_pre = time_fn(&cfg, || {
        reorder_2d_scatter(&x, &mut out, n, n);
        black_box(&out);
    })
    .mean;

    // serial kernel: the roofline model is per-core bandwidth
    let plan = Dct2::with_policy(n, n, ExecPolicy::Serial);
    let h2 = onesided_len(n);
    let spec: Vec<C64> = (0..n * h2).map(|_| C64::new(rng.normal(), rng.normal())).collect();
    let t_post = time_fn(&cfg, || {
        plan.postprocess(&spec, &mut out);
        black_box(&out);
    })
    .mean;

    let pre_traffic = preprocess_traffic(n, n);
    let post_traffic = postprocess_traffic(n, n);
    let mut t = Table::new(&["Kernel", "time ms", "bytes moved", "achieved GB/s", "Mem. BW %"]);
    for (name, time, traffic) in
        [("preprocess", t_pre, pre_traffic), ("postprocess", t_post, post_traffic)]
    {
        let frac = achieved_fraction(traffic, time, machine.copy_bw);
        t.row(&[
            name.to_string(),
            format!("{:.3}", time * 1e3),
            format!("{:.1} MB", traffic.bytes() / 1e6),
            format!("{:.2}", traffic.bytes() / time / 1e9),
            format!("{:.1}%", frac * 100.0),
        ]);
    }
    t.print();
    println!(
        "shape check (paper: both kernels >75% Mem.BW, compute-light): the kernels \
         should sit well above 50% of the copy roofline, confirming memory-bound."
    );
}
