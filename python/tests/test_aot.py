"""AOT path integrity: manifest schema, HLO text validity, determinism."""
import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_manifest_entries_reference_known_pipelines():
    for name, pipeline, shapes in aot.manifest_entries():
        assert pipeline in model.PIPELINES, name
        assert all(len(s) in (0, 1, 2) for s in shapes), name


def test_manifest_names_unique():
    names = [n for n, _, _ in aot.manifest_entries()]
    assert len(names) == len(set(names))


def test_hlo_text_lowering_smoke():
    """Lower one small pipeline and sanity-check the HLO text structure."""
    text = aot.to_hlo_text(model.PIPELINES["dct2d"], [(8, 8)])
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "fft" in text.lower()  # the RFFT stage must survive lowering
    # text interchange requirement: parseable-ish, non-proto
    assert not text.startswith("\x08")


def test_large_constants_not_elided():
    """REGRESSION: the default HLO printer elides big literals as
    `constant({...})`, which the XLA text parser silently zero-fills —
    the twiddle tables / cosine matrices would vanish from the artifact
    (observed as all-zero outputs from the Rust runtime)."""
    text = aot.to_hlo_text(model.PIPELINES["matmul_dct2d"], [(64, 64)])
    assert "constant({..." not in text
    # the 64x64 cosine matrix must be printed elementwise
    assert text.count(",") > 64 * 64


def test_hlo_lowering_deterministic():
    a = aot.to_hlo_text(model.PIPELINES["dct1d_n"], [(32,)])
    b = aot.to_hlo_text(model.PIPELINES["dct1d_n"], [(32,)])
    assert a == b


def test_out_specs_shapes():
    specs = aot.out_specs(model.PIPELINES["rfft2d"], [(8, 8)])
    assert [s["shape"] for s in specs] == [[8, 5], [8, 5]]
    specs = aot.out_specs(model.PIPELINES["placement_force"], [(8, 8)])
    assert len(specs) == 3 and all(s["shape"] == [8, 8] for s in specs)


def test_cli_writes_manifest(tmp_path):
    """End-to-end aot CLI on a filtered subset (keeps the test fast)."""
    env = dict(os.environ)
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out),
         "--filter", "dct1d_n_1024"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["dtype"] == "f32"
    assert len(manifest["entries"]) == 1
    e = manifest["entries"][0]
    assert (out / e["file"]).exists()
    assert e["inputs"] == [{"shape": [1024], "dtype": "f32"}]
    assert e["outputs"] == [{"shape": [1024], "dtype": "f32"}]
