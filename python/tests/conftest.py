import jax
import pytest

# The paper evaluates in double precision; enable x64 so the oracles are
# exact enough to arbitrate (f32 paths are tested with looser tolerances).
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    import numpy as np

    return np.random.default_rng(1234)
