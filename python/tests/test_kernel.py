"""Kernel vs oracle: the CORE correctness signal (L1 against ref.py).

Every preprocess/postprocess kernel (both the jnp and the Pallas
implementation) is checked against the direct O(N^2) cosine/sine-matrix
oracles, over even/odd/rectangular/degenerate shapes and both dtypes.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import common as C
from compile.kernels import ref as R

SHAPES_2D = [(4, 4), (8, 8), (16, 16), (6, 10), (5, 7), (1, 8), (8, 1), (32, 8)]
SIZES_1D = [1, 2, 3, 4, 8, 15, 16, 31, 64]


def _rand(rng, shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def _close(got, want, dtype=np.float64):
    got, want = np.asarray(got), np.asarray(want)
    if dtype == np.float32:
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-9)


# ------------------------------------------------------------- 1D DCT ----

@pytest.mark.parametrize("n", SIZES_1D)
@pytest.mark.parametrize(
    "algo", ["dct1d_4n", "dct1d_2n_mirror", "dct1d_2n_pad", "dct1d_n"]
)
def test_1d_algorithms_match_oracle(rng, n, algo):
    x = _rand(rng, n)
    _close(M.PIPELINES[algo](x), R.dct1d_ref(x))


@pytest.mark.parametrize("n", SIZES_1D)
def test_idct1d_matches_oracle(rng, n):
    x = _rand(rng, n)
    _close(M.idct1d(x), R.idct1d_ref(x))


@pytest.mark.parametrize("n", [8, 15, 16])
def test_dct1d_n_pallas(rng, n):
    x = _rand(rng, n)
    _close(M.dct1d_n(x, impl="pallas"), R.dct1d_ref(x))


def test_1d_batched_rows(rng):
    """1D kernels accept matrices (the row-column baseline feeds them)."""
    x = _rand(rng, (5, 16))
    want = np.stack([np.asarray(R.dct1d_ref(x[i])) for i in range(5)])
    _close(M.dct1d_n(x), want)
    _close(M.idct1d(x), np.stack([np.asarray(R.idct1d_ref(x[i])) for i in range(5)]))


def test_reorder_1d_is_permutation():
    n = 16
    x = jnp.arange(n, dtype=jnp.float64)
    v = C.reorder_1d(x)
    assert sorted(np.asarray(v).tolist()) == list(range(n))
    _close(C.unreorder_1d(v), x)


# ------------------------------------------------------------- 2D DCT ----

@pytest.mark.parametrize("shape", SHAPES_2D)
def test_dct2d_matches_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.dct2d(x), R.dct2d_ref(x))


@pytest.mark.parametrize("shape", SHAPES_2D)
def test_idct2d_matches_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.idct2d(x), R.idct2d_ref(x))


@pytest.mark.parametrize("shape", [(8, 8), (6, 10), (16, 16), (5, 7)])
def test_dct2d_pallas_matches_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.dct2d(x, impl="pallas"), R.dct2d_ref(x))
    _close(M.idct2d(x, impl="pallas"), R.idct2d_ref(x))


@pytest.mark.parametrize("shape", SHAPES_2D)
def test_row_column_baseline_matches_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.rc_dct2d(x), R.dct2d_ref(x))
    _close(M.rc_idct2d(x), R.idct2d_ref(x))


def test_fused_equals_row_column(rng):
    """The paper's central claim of exactness: fusion changes no numerics
    beyond roundoff."""
    x = _rand(rng, (24, 24))
    _close(M.dct2d(x), M.rc_dct2d(x))
    _close(M.idct2d(x), M.rc_idct2d(x))


def test_reorder_2d_is_permutation():
    x = jnp.arange(48, dtype=jnp.float64).reshape(6, 8)
    v = C.reorder_2d(x)
    assert sorted(np.asarray(v).ravel().tolist()) == list(range(48))
    _close(C.unreorder_2d(v), x)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dct2d_dtypes(rng, dtype):
    x = _rand(rng, (16, 16), dtype)
    assert np.asarray(M.dct2d(x)).dtype == dtype
    _close(M.dct2d(x), R.dct2d_ref(x), dtype)


# -------------------------------------------------- hypothesis sweeps ----

@settings(max_examples=30, deadline=None)
@given(
    n1=st.integers(min_value=1, max_value=24),
    n2=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_dct2d_roundtrip(n1, n2, seed):
    """idct2d(dct2d(x)) == x for arbitrary (odd/even/degenerate) shapes."""
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((n1, n2)))
    _close(M.idct2d(M.dct2d(x)), x)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_1d_all_algorithms_agree(n, seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(n))
    a = M.dct1d_n(x)
    _close(M.dct1d_4n(x), a)
    _close(M.dct1d_2n_mirror(x), a)
    _close(M.dct1d_2n_pad(x), a)


@settings(max_examples=20, deadline=None)
@given(
    n1=st.integers(min_value=2, max_value=16),
    n2=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_linearity(n1, n2, seed):
    g = np.random.default_rng(seed)
    x = jnp.asarray(g.standard_normal((n1, n2)))
    y = jnp.asarray(g.standard_normal((n1, n2)))
    _close(M.dct2d(2.5 * x - y), 2.5 * M.dct2d(x) - M.dct2d(y))


@settings(max_examples=15, deadline=None)
@given(
    n1=st.sampled_from([4, 6, 8, 12]),
    n2=st.sampled_from([4, 6, 8, 12]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_pallas_equals_jnp(n1, n2, seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((n1, n2)))
    _close(M.dct2d(x, impl="pallas"), M.dct2d(x))
    _close(M.idct2d(x, impl="pallas"), M.idct2d(x))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_hermitian_symmetry(n, seed):
    """RFFT of the reordered real input is the onesided half of the full
    spectrum -- the redundancy the postprocess exploits (Eq. 12)."""
    x = np.random.default_rng(seed).standard_normal(n)
    v = np.asarray(C.reorder_1d(jnp.asarray(x)))
    full = np.fft.fft(v)
    half = np.fft.rfft(v)
    for k in range(len(half)):
        np.testing.assert_allclose(full[k], half[k], atol=1e-10)
        np.testing.assert_allclose(full[(n - k) % n], np.conj(half[k]), atol=1e-10)
