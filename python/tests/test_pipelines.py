"""Application pipelines (L2): image compression and DREAMPlace force."""
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as R


def _close(got, want, tol=1e-8):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_compress_matches_oracle(rng):
    x = jnp.asarray(rng.standard_normal((16, 16)))
    _close(M.image_compress(x, jnp.asarray(0.7)), R.compress_ref(x, 0.7))


def test_compress_eps_zero_is_identity(rng):
    x = jnp.asarray(rng.standard_normal((12, 12)))
    _close(M.image_compress(x, jnp.asarray(0.0)), x)


def test_compress_eps_huge_zeroes_everything(rng):
    x = jnp.asarray(rng.standard_normal((12, 12)))
    _close(M.image_compress(x, jnp.asarray(1e12)), jnp.zeros_like(x))


def test_compress_energy_decreases(rng):
    """Thresholding can only remove spectral energy (Parseval-monotone)."""
    x = jnp.asarray(rng.standard_normal((16, 16)))
    b = R.dct2d_ref(x)
    for eps in [0.1, 1.0, 5.0]:
        c = jnp.where(jnp.abs(b) >= eps, b, 0.0)
        assert float(jnp.sum(c * c)) <= float(jnp.sum(b * b)) + 1e-12


def test_placement_force_is_gradient_of_potential(rng):
    """xi ~ -grad(phi): spectral force field vs central differences of the
    spectral potential, on a smooth density (loose tolerance: different
    discretizations of the same derivative)."""
    n = 64
    i = np.arange(n)
    gx, gy = np.meshgrid(i, i, indexing="ij")
    rho = np.exp(-((gx - 32.0) ** 2 + (gy - 24.0) ** 2) / 60.0)
    phi, xi_x, xi_y = M.placement_force(jnp.asarray(rho))
    phi = np.asarray(phi)
    fd_x = np.zeros_like(phi)
    fd_x[1:-1, :] = (phi[2:, :] - phi[:-2, :]) / 2.0
    fd_y = np.zeros_like(phi)
    fd_y[:, 1:-1] = (phi[:, 2:] - phi[:, :-2]) / 2.0
    # compare in the interior, relative to the field magnitude
    sx = np.abs(np.asarray(xi_x)[4:-4, 4:-4] + fd_x[4:-4, 4:-4]).max()
    scale = np.abs(fd_x).max()
    assert sx < 0.15 * scale, f"xi_x vs -grad phi mismatch: {sx} vs {scale}"
    sy = np.abs(np.asarray(xi_y)[4:-4, 4:-4] + fd_y[4:-4, 4:-4]).max()
    assert sy < 0.15 * np.abs(fd_y).max()


def test_placement_potential_solves_poisson(rng):
    """Discrete spectral check: DCT2D(phi) * (wu^2 + wv^2) == DCT2D(rho)
    away from the gauge-fixed (0,0) mode."""
    n = 32
    rho = rng.standard_normal((n, n))
    phi, _, _ = M.placement_force(jnp.asarray(rho))
    a_rho = np.asarray(R.dct2d_ref(jnp.asarray(rho)))
    a_phi = np.asarray(R.dct2d_ref(phi))
    wu = np.pi * np.arange(n)[:, None] / n
    wv = np.pi * np.arange(n)[None, :] / n
    w2 = wu**2 + wv**2
    lhs = (a_phi * w2)[1:, 1:]
    rhs = a_rho[1:, 1:]
    np.testing.assert_allclose(lhs, rhs, rtol=1e-6, atol=1e-6)


def test_rfft2d_matches_numpy(rng):
    x = rng.standard_normal((12, 20))
    re, im = M.rfft2d(jnp.asarray(x))
    want = np.fft.rfft2(x)
    _close(re, want.real)
    _close(im, want.imag)


def test_irfft2d_inverts_rfft2d(rng):
    x = rng.standard_normal((10, 14))
    re, im = M.rfft2d(jnp.asarray(x))
    _close(M.irfft2d(re, im, 10, 14), x)
