"""DST family via the fused paradigm (paper §III-D extensibility)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref as R


def _close(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-8, atol=1e-9)


@pytest.mark.parametrize("shape", [(4, 4), (8, 8), (6, 10), (5, 7), (16, 16)])
def test_dst2d_matches_sine_oracle(rng, shape):
    x = jnp.asarray(rng.standard_normal(shape))
    _close(M.dst2d(x), R.dst2d_ref(x))


@pytest.mark.parametrize("shape", [(8, 8), (6, 10)])
def test_idst2d_inverts(rng, shape):
    x = jnp.asarray(rng.standard_normal(shape))
    _close(M.idst2d(M.dst2d(x)), x)


def test_dst1d_oracle_definition(rng):
    """DST-II(x)_k == DCT-II((-1)^n x)_{N-1-k} — the fold identity the
    fused implementation relies on."""
    n = 12
    x = rng.standard_normal(n)
    sign = (-1.0) ** np.arange(n)
    a = np.asarray(R.dst1d_ref(jnp.asarray(x)))
    b = np.asarray(R.dct1d_ref(jnp.asarray(x * sign)))[::-1]
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    n1=st.integers(min_value=2, max_value=16),
    n2=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_dst_roundtrip(n1, n2, seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((n1, n2)))
    _close(M.idst2d(M.dst2d(x)), x)
