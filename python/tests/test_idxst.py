"""IDXST and the DREAMPlace 2D combinations (paper §V-B, Eqs. 21-22)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref as R


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape))


def _close(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-8, atol=1e-9)


def test_idxst_definition(rng):
    """Eq. (21): IDXST({x_n})_k = (-1)^k IDCT({x_{N-n}})_k, x_N = 0."""
    n = 12
    x = _rand(rng, n)
    xs = jnp.concatenate([jnp.zeros(1), jnp.flip(x[1:])])
    want = R.idct1d_ref(xs) * jnp.asarray((-1.0) ** np.arange(n))
    _close(R.idxst1d_ref(x), want)


def test_idxst_ignores_dc(rng):
    """x_0 never enters Eq. (21) (the sine series has no DC term)."""
    x = _rand(rng, 9)
    y = x.at[0].set(123.456)
    _close(R.idxst1d_ref(x), R.idxst1d_ref(y))


@pytest.mark.parametrize("shape", [(4, 4), (8, 8), (6, 10), (5, 7), (16, 16)])
def test_fused_combos_match_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.idct_idxst(x), R.idct_idxst_ref(x))
    _close(M.idxst_idct(x), R.idxst_idct_ref(x))


@pytest.mark.parametrize("shape", [(8, 8), (6, 10)])
def test_row_column_combos_match_oracle(rng, shape):
    x = _rand(rng, shape)
    _close(M.rc_idct_idxst(x), R.idct_idxst_ref(x))
    _close(M.rc_idxst_idct(x), R.idxst_idct_ref(x))


def test_combos_transpose_relation(rng):
    """Eq. (22): IDCT_IDXST(x) = IDXST_IDCT(x^T)^T."""
    x = _rand(rng, (8, 12))
    _close(M.idct_idxst(x), M.idxst_idct(x.T).T)


@settings(max_examples=20, deadline=None)
@given(
    n1=st.integers(min_value=2, max_value=16),
    n2=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_fused_equals_row_column(n1, n2, seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((n1, n2)))
    _close(M.idct_idxst(x), M.rc_idct_idxst(x))
    _close(M.idxst_idct(x), M.rc_idxst_idct(x))
