"""L2: JAX transform pipelines composing the L1 kernels with XLA's FFT.

Each public function is a complete three-stage pipeline

    preprocess (L1 kernel)  ->  rfft/irfft (XLA, the cuFFT analogue)
                            ->  postprocess (L1 kernel)

plus the baselines the paper benchmarks against (row-column, direct
matmul) and the application pipelines (image compression, DREAMPlace
electric-force). `aot.py` lowers every entry of PIPELINES to HLO text once
("make artifacts"); the Rust coordinator executes the artifacts via PJRT
and never calls back into Python.

`impl` selects the kernel implementation: "jnp" (plain jnp bodies, the
fastest XLA-CPU lowering, used for artifacts) or "pallas"
(pl.pallas_call(interpret=True) bodies -- the TPU-shaped L1 kernels,
correctness-verified on CPU and compiled into one artifact as proof of the
L1 -> HLO -> PJRT path).
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp
import numpy as np

from .kernels import compress as kcompress
from .kernels import dct1d as k1
from .kernels import dct2d as k2
from .kernels import idct2d as ki
from .kernels import idxst as kx

__all__ = [
    "dct2d", "idct2d",
    "dct1d_4n", "dct1d_2n_mirror", "dct1d_2n_pad", "dct1d_n", "idct1d",
    "idct_idxst", "idxst_idct", "dst2d", "idst2d",
    "rc_dct2d", "rc_idct2d", "rc_idct_idxst", "rc_idxst_idct",
    "matmul_dct2d",
    "rfft2d", "irfft2d",
    "image_compress", "placement_force",
    "PIPELINES",
]


# ------------------------------------------------------------- 2D DCT ----

def dct2d(x, impl: str = "jnp"):
    """Fused 2D DCT-II: Eq. (13) reorder -> rfft2 -> Eq. (14) combine."""
    if impl == "pallas":
        v = k2.dct2d_preprocess_pallas(x)
        s = jnp.fft.rfft2(v)
        return k2.dct2d_postprocess_pallas(
            jnp.real(s).astype(x.dtype), jnp.imag(s).astype(x.dtype), x.shape[1]
        )
    v = k2.dct2d_preprocess_jnp(x)
    s = jnp.fft.rfft2(v)
    return k2.dct2d_postprocess_jnp(
        jnp.real(s).astype(x.dtype), jnp.imag(s).astype(x.dtype), x.shape[1]
    )


def idct2d(x, impl: str = "jnp"):
    """Fused 2D IDCT: Eq. (15) spectrum build -> irfft2 -> Eq. (16)."""
    if impl == "pallas":
        vre, vim = ki.idct2d_preprocess_pallas(x)
    else:
        vre, vim = ki.idct2d_preprocess_jnp(x)
    v = jnp.fft.irfft2(
        (vre + 1j * vim).astype(jnp.complex128 if x.dtype == jnp.float64
                                else jnp.complex64),
        s=x.shape,
    ).astype(x.dtype)
    if impl == "pallas":
        return ki.idct2d_postprocess_pallas(v)
    return ki.idct2d_postprocess_jnp(v)


# ------------------------------------------------------------- 1D DCT ----

def _rfft_split(v, dtype):
    s = jnp.fft.rfft(v)
    return jnp.real(s).astype(dtype), jnp.imag(s).astype(dtype)


def dct1d_4n(x):
    """Algorithm 1 lines 1-4: DCT via 4N-point RFFT."""
    n = x.shape[-1]
    vre, vim = _rfft_split(k1.dct_4n_preprocess(x), x.dtype)
    return k1.dct_4n_postprocess(vre, vim, n)


def dct1d_2n_mirror(x):
    """Algorithm 1 lines 5-8: DCT via mirrored 2N-point RFFT."""
    n = x.shape[-1]
    vre, vim = _rfft_split(k1.dct_2n_mirror_preprocess(x), x.dtype)
    return k1.dct_2n_mirror_postprocess(vre, vim, n)


def dct1d_2n_pad(x):
    """Algorithm 1 lines 9-12: DCT via zero-padded 2N-point RFFT."""
    n = x.shape[-1]
    vre, vim = _rfft_split(k1.dct_2n_pad_preprocess(x), x.dtype)
    return k1.dct_2n_pad_postprocess(vre, vim, n)


def dct1d_n(x, impl: str = "jnp"):
    """Algorithm 1 lines 13-16: DCT via N-point RFFT (the fastest)."""
    n = x.shape[-1]
    if impl == "pallas":
        v = k1.dct_n_preprocess_pallas(x)
        vre, vim = _rfft_split(v, x.dtype)
        return k1.dct_n_postprocess_pallas(vre, vim, n)
    vre, vim = _rfft_split(k1.dct_n_preprocess(x), x.dtype)
    return k1.dct_n_postprocess(vre, vim, n)


def idct1d(x):
    """Inverse DCT via N-point IRFFT (1D restriction of Eq. 15/16)."""
    n = x.shape[-1]
    vre, vim = k1.idct_n_preprocess(x)
    cdt = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    v = jnp.fft.irfft((vre + 1j * vim).astype(cdt), n=n, axis=-1).astype(x.dtype)
    return k1.idct_n_postprocess(v)


# -------------------------------------------------- DREAMPlace combos ----

def idct_idxst(x, impl: str = "jnp"):
    """Eq. (22) IDCT_IDXST as ONE fused three-stage transform."""
    if impl == "pallas":
        return kx.sign_rows_pallas(idct2d(kx.shift_rows_pallas(x), impl))
    return kx.sign_rows(idct2d(kx.shift_rows(x), impl))


def idxst_idct(x, impl: str = "jnp"):
    """Eq. (22) IDXST_IDCT as ONE fused three-stage transform."""
    return kx.sign_cols(idct2d(kx.shift_cols(x), impl))


# ----------------------------------------------- row-column baselines ----

def _along_rows(fn, x):
    """Apply a last-axis 1D transform along axis 1 (rows of the matrix)."""
    return fn(x)


def _along_cols(fn, x):
    """Apply a last-axis 1D transform along axis 0 via two transposes."""
    return fn(x.T).T


def rc_dct2d(x):
    """Row-column 2D DCT baseline: 1D N-point DCT rows, transpose, cols.

    This is the paper's own strengthened baseline ("we implement and
    optimize the row-column method based on our 1D DCT/IDCT
    implementation"): each 1D pass is the best (N-point) algorithm; the
    cost is the extra full-matrix passes + transposes that Figure 5 counts.
    """
    return _along_cols(dct1d_n, _along_rows(dct1d_n, x))


def rc_idct2d(x):
    """Row-column 2D IDCT baseline."""
    return _along_cols(idct1d, _along_rows(idct1d, x))


def _idxst1d(x):
    return kx.sign_last(idct1d(kx.shift_last(x)))


def rc_idct_idxst(x):
    """Row-column IDCT_IDXST baseline (1D IDCT rows, 1D IDXST cols)."""
    return _along_cols(_idxst1d, _along_rows(idct1d, x))


def rc_idxst_idct(x):
    """Row-column IDXST_IDCT baseline (1D IDXST rows, 1D IDCT cols)."""
    return _along_cols(idct1d, _along_rows(_idxst1d, x))


def dst2d(x, impl: str = "jnp"):
    """Fused 2D DST-II via the same three-stage core (§III-D):
    DST2 = reverse-both-axes . DCT2 . checkerboard-sign, an O(N^2) fold
    validated against the direct sine-matrix oracle."""
    n1, n2 = x.shape
    sign = jnp.asarray(
        np.fromfunction(lambda i, j: (-1.0) ** ((i + j) % 2), (n1, n2)), x.dtype
    )
    y = dct2d(x * sign, impl)
    return jnp.flip(jnp.flip(y, axis=0), axis=1)


def idst2d(x, impl: str = "jnp"):
    """Exact inverse of :func:`dst2d`."""
    n1, n2 = x.shape
    sign = jnp.asarray(
        np.fromfunction(lambda i, j: (-1.0) ** ((i + j) % 2), (n1, n2)), x.dtype
    )
    rev = jnp.flip(jnp.flip(x, axis=0), axis=1)
    return idct2d(rev, impl) * sign


def matmul_dct2d(x):
    """Direct O(N^2 . N) separable matmul DCT.

    Stand-in for the closed-source MATLAB gpuArray dct2 column of Table V:
    a correct, general, but order-of-magnitude slower library baseline.
    """
    from .kernels.ref import dct_mat

    c1 = jnp.asarray(dct_mat(x.shape[0]), x.dtype)
    c2 = jnp.asarray(dct_mat(x.shape[1]), x.dtype)
    return c1 @ x @ c2.T


# ------------------------------------------------------ FFT reference ----

def rfft2d(x):
    """Raw 2D RFFT (the paper's reference column: the attainable floor)."""
    s = jnp.fft.rfft2(x)
    return jnp.real(s).astype(x.dtype), jnp.imag(s).astype(x.dtype)


def irfft2d(re, im, n1: int, n2: int):
    """Raw 2D IRFFT reference."""
    cdt = jnp.complex128 if re.dtype == jnp.float64 else jnp.complex64
    return jnp.fft.irfft2((re + 1j * im).astype(cdt), s=(n1, n2)).astype(re.dtype)


# -------------------------------------------------------- applications ----

def image_compress(x, eps, impl: str = "jnp"):
    """Paper Algorithm 3: DCT -> Eq. (20) threshold -> IDCT, fully fused."""
    b = dct2d(x, impl)
    if impl == "pallas":
        c = kcompress.threshold_pallas(b, eps)
    else:
        c = kcompress.threshold_jnp(b, eps)
    return idct2d(c, impl)


def placement_force(density, impl: str = "jnp"):
    """Paper Algorithm 4: DREAMPlace electric potential + force step.

    Spectral solve of Poisson's equation on the density map (ePlace
    formulation): with a_uv = DCT2D(rho) and frequencies w_u = pi u / N1,
    w_v = pi v / N2,

        phi  = IDCT2D      ( a_uv          / (w_u^2 + w_v^2) )
        xi_x = IDXST_IDCT  ( a_uv  w_u     / (w_u^2 + w_v^2) )
        xi_y = IDCT_IDXST  ( a_uv  w_v     / (w_u^2 + w_v^2) )

    (the (0,0) mode is gauge-fixed to zero). Returns (phi, xi_x, xi_y).
    Lines 1 and 3 of Algorithm 4 (density map build, coefficient scaling)
    live in the Rust app for the end-to-end driver; this pipeline is the
    transform-heavy core that Table VII times.
    """
    n1, n2 = density.shape
    a = dct2d(density, impl)
    wu = jnp.asarray(np.pi * np.arange(n1) / n1, density.dtype)[:, None]
    wv = jnp.asarray(np.pi * np.arange(n2) / n2, density.dtype)[None, :]
    w2 = wu * wu + wv * wv
    inv = jnp.where(w2 > 0, 1.0 / jnp.where(w2 > 0, w2, 1.0), 0.0)
    phi = idct2d(a * inv, impl)
    # Axis pairing: the gradient along axis 0 (k1) turns the cosine series
    # in k1 into a sine series => IDXST along rows => idct_idxst (which
    # applies IDXST along axis 0, IDCT along axis 1); symmetric for xi_y.
    xi_x = idct_idxst(a * wu * inv, impl)
    xi_y = idxst_idct(a * wv * inv, impl)
    return phi, xi_x, xi_y


# ----------------------------------------------------------- registry ----

def _p(fn, **kw):
    return partial(fn, **kw) if kw else fn

#: name -> (callable, n_array_inputs_described_in_aot)
PIPELINES = {
    "dct2d": _p(dct2d),
    "dct2d_pallas": _p(dct2d, impl="pallas"),
    "idct2d": _p(idct2d),
    "idct2d_pallas": _p(idct2d, impl="pallas"),
    "dct1d_4n": dct1d_4n,
    "dct1d_2n_mirror": dct1d_2n_mirror,
    "dct1d_2n_pad": dct1d_2n_pad,
    "dct1d_n": _p(dct1d_n),
    "idct1d": idct1d,
    "idct_idxst": _p(idct_idxst),
    "idxst_idct": _p(idxst_idct),
    "rc_dct2d": rc_dct2d,
    "rc_idct2d": rc_idct2d,
    "rc_idct_idxst": rc_idct_idxst,
    "rc_idxst_idct": rc_idxst_idct,
    "matmul_dct2d": matmul_dct2d,
    "dst2d": _p(dst2d),
    "idst2d": _p(idst2d),
    "rfft2d": rfft2d,
    "image_compress": _p(image_compress),
    "placement_force": _p(placement_force),
}
