"""L1: Pallas pre/postprocessing kernels for the three-stage paradigm.

Modules:
  common   -- twiddles, butterfly reorders, the pallas_call adapter
  ref      -- pure-jnp O(N^2) oracles (direct cosine/sine matrices)
  dct1d    -- the four 1D DCT-via-FFT algorithms + 1D IDCT (Algorithm 1)
  dct2d    -- fused 2D DCT preprocess/postprocess (Algorithm 2 fwd)
  idct2d   -- fused 2D IDCT preprocess/postprocess (Algorithm 2 inv)
  idxst    -- IDXST folds for the DREAMPlace transforms (Eq. 21/22)
  compress -- magnitude-threshold compression kernel (Eq. 20)
"""
from . import common, compress, dct1d, dct2d, idct2d, idxst, ref  # noqa: F401
