"""Pure-jnp correctness oracles for every transform in the library.

These are direct O(N^2) cosine/sine-matrix implementations, deliberately
independent of any FFT so they can arbitrate between the FFT-based fast
paths (kernels + model pipelines) and the Rust native backend.

Conventions (see DESIGN.md "Mathematical conventions"):
  dct(x)[k]  = 2 sum_n x[n] cos(pi k (2n+1) / 2N)      (DCT-II, scipy-style)
  idct       = exact inverse of dct
  idxst(x)_k = (-1)^k idct({x[N-n]})_k with x[N] := 0   (DREAMPlace Eq. 21)
2D transforms are separable applications along each axis.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "dct_mat",
    "idct_mat",
    "idxst_mat",
    "dct1d_ref",
    "idct1d_ref",
    "idxst1d_ref",
    "dct2d_ref",
    "idct2d_ref",
    "idct_idxst_ref",
    "idxst_idct_ref",
    "compress_ref",
    "dst_mat",
    "dst1d_ref",
    "dst2d_ref",
]


def dct_mat(n: int, dtype=np.float64) -> np.ndarray:
    """DCT-II matrix C with (C x)[k] = 2 sum_n x[n] cos(pi k (2n+1)/2N)."""
    k = np.arange(n)[:, None]
    m = np.arange(n)[None, :]
    return (2.0 * np.cos(np.pi * k * (2 * m + 1) / (2 * n))).astype(dtype)


def idct_mat(n: int, dtype=np.float64) -> np.ndarray:
    """Exact inverse of :func:`dct_mat` in closed form (DCT-III / 2N)."""
    k = np.arange(n)[:, None]
    m = np.arange(n)[None, :]
    c = 2.0 * np.cos(np.pi * m * (2 * k + 1) / (2 * n))
    c[:, 0] = 1.0
    return (c / (2.0 * n)).astype(dtype)


def idxst_mat(n: int, dtype=np.float64) -> np.ndarray:
    """IDXST matrix: idxst(x) = sign . idct(S x), S the zero reverse-shift."""
    s = np.zeros((n, n))
    for i in range(1, n):
        s[i, n - i] = 1.0  # (S x)[i] = x[N - i]; (S x)[0] = x[N] = 0
    sign = np.diag((-1.0) ** np.arange(n))
    return (sign @ idct_mat(n) @ s).astype(dtype)


def _apply_last(mat: np.ndarray, x):
    return jnp.matmul(x, jnp.asarray(mat, dtype=x.dtype).T)


def _apply_first(mat: np.ndarray, x):
    return jnp.matmul(jnp.asarray(mat, dtype=x.dtype), x)


def dct1d_ref(x):
    """DCT-II along the last axis."""
    return _apply_last(dct_mat(x.shape[-1]), x)


def idct1d_ref(x):
    """Inverse DCT along the last axis."""
    return _apply_last(idct_mat(x.shape[-1]), x)


def idxst1d_ref(x):
    """IDXST along the last axis."""
    return _apply_last(idxst_mat(x.shape[-1]), x)


def dct2d_ref(x):
    """Separable 2D DCT-II: rows then columns (order is immaterial)."""
    return _apply_first(dct_mat(x.shape[0]), _apply_last(dct_mat(x.shape[1]), x))


def idct2d_ref(x):
    """Separable 2D inverse DCT."""
    return _apply_first(idct_mat(x.shape[0]), _apply_last(idct_mat(x.shape[1]), x))


def idct_idxst_ref(x):
    """Paper Eq. (22): 1D IDCT along rows, then 1D IDXST along columns."""
    return _apply_first(idxst_mat(x.shape[0]), _apply_last(idct_mat(x.shape[1]), x))


def idxst_idct_ref(x):
    """Paper Eq. (22): 1D IDXST along rows, then 1D IDCT along columns."""
    return _apply_first(idct_mat(x.shape[0]), _apply_last(idxst_mat(x.shape[1]), x))


def dst_mat(n: int, dtype=np.float64) -> np.ndarray:
    """DST-II matrix: (S x)[k] = 2 sum_n x[n] sin(pi (k+1)(2n+1)/2N)."""
    k = np.arange(n)[:, None]
    m = np.arange(n)[None, :]
    return (2.0 * np.sin(np.pi * (k + 1) * (2 * m + 1) / (2 * n))).astype(dtype)


def dst1d_ref(x):
    """DST-II along the last axis."""
    return _apply_last(dst_mat(x.shape[-1]), x)


def dst2d_ref(x):
    """Separable 2D DST-II."""
    return _apply_first(dst_mat(x.shape[0]), _apply_last(dst_mat(x.shape[1]), x))


def compress_ref(x, eps):
    """Image-compression oracle, Alg. 3: dct -> magnitude threshold -> idct."""
    b = dct2d_ref(x)
    c = jnp.where(jnp.abs(b) >= eps, b, jnp.zeros_like(b))
    return idct2d_ref(c)
