"""L1 kernel for magnitude-threshold compression (paper Algorithm 3, Eq. 20).

The paper notes this elementwise filter fuses into the DCT postprocess /
IDCT preprocess, making p = 1 in the Amdahl model -- the compression
pipeline inherits the full transform speedup. The L2 `image_compress`
pipeline composes it between the fused 2D DCT and 2D IDCT so XLA fuses it
with the neighbouring stages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import pallas_wrap

__all__ = ["threshold_jnp", "threshold_pallas"]


def threshold_jnp(b, eps):
    """Eq. (20): zero every coefficient with |B_ij| < eps."""
    return jnp.where(jnp.abs(b) >= eps, b, jnp.zeros_like(b))


def threshold_pallas(b, eps):
    """Pallas form of Eq. (20). `eps` enters as a (1,1) scalar tile."""
    e = jnp.reshape(eps.astype(b.dtype) if hasattr(eps, "astype")
                    else jnp.asarray(eps, b.dtype), (1, 1))
    return pallas_wrap(
        lambda bv, ev: jnp.where(jnp.abs(bv) >= ev[0, 0], bv, jnp.zeros_like(bv)),
        jax.ShapeDtypeStruct(b.shape, b.dtype),
        b, e,
    )
