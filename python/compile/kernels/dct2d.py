"""L1 kernels for the fused 2D DCT (paper Algorithm 2, Sections III-A/B).

Three-stage decomposition:
  preprocess  : butterfly reorder of both axes (Eq. 13)       -- O(N1 N2)
  2D RFFT     : performed by the L2 pipeline (jnp.fft.rfft2)  -- O(N log N)
  postprocess : twiddle + conjugate-symmetry combine (Eq. 14,
                corrected; see DESIGN.md)                     -- O(N1 N2)

The postprocess consumes the *onesided* spectrum of shape
(N1, H = N2//2 + 1), exactly like the paper's CUDA kernel consumes the
onesided cuFFT output: each output 4-tuple {y(k1,k2), y(N1-k1,k2),
y(k1,N2-k2), y(N1-k1,N2-k2)} is produced from the two spectrum reads
{V(k1,k2), V((N1-k1)%N1,k2)}. Here the same data reuse is expressed
vectorized over the whole tile instead of per-thread.

Every kernel has two interchangeable implementations:
  *_jnp    — plain jnp (used for AOT artifacts: fastest XLA-CPU lowering)
  *_pallas — pl.pallas_call(interpret=True) (the TPU-shaped L1 kernel; the
             deployment path on a real TPU, correctness-checked on CPU)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import reorder_2d, twiddle

__all__ = [
    "dct2d_preprocess_jnp",
    "dct2d_preprocess_pallas",
    "dct2d_postprocess_jnp",
    "dct2d_postprocess_pallas",
]


# --------------------------------------------------------------------------
# preprocess: Eq. (13) butterfly reorder
# --------------------------------------------------------------------------

def dct2d_preprocess_jnp(x):
    """Fused 2D butterfly reorder (Eq. 13), plain-jnp implementation."""
    return reorder_2d(x)


def _pre2d_kernel(x_ref, o_ref):
    x = x_ref[...]
    v = jnp.concatenate([x[0::2, :], jnp.flip(x[1::2, :], axis=0)], axis=0)
    w = jnp.concatenate([v[:, 0::2], jnp.flip(v[:, 1::2], axis=1)], axis=1)
    o_ref[...] = w


def dct2d_preprocess_pallas(x):
    """Pallas version of the Eq. (13) reorder.

    One VMEM-resident block per call. On a real TPU this would be tiled by
    BlockSpec over 128x128 tiles (the reorder touches element (i, j) and
    its mirrored partners only, so each output tile needs at most 4 input
    tiles); interpret mode executes the same kernel body on CPU.
    """
    return pl.pallas_call(
        _pre2d_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


# --------------------------------------------------------------------------
# postprocess: corrected Eq. (14) on the onesided spectrum
# --------------------------------------------------------------------------

def _post2d_math(vre, vim, n2, ccol_r, ccol_i, crow_r, crow_i):
    """Shared math for both implementations.

    vre/vim: onesided rfft2 spectrum, shape (N1, H), H = N2//2 + 1.
    ccol_*:  twiddle a(k1) = e^{-j pi k1 / 2 N1}, shape (N1, 1).
    crow_*:  twiddle b(k2) = e^{-j pi k2 / 2 N2}, full length N2.

    X(k1,k2) = 2 Re( a(k1) * [ b(k2) V(k1,k2)
                             + conj(b(k2)) conj(V((N1-k1)%N1, k2)) ] )
    with the k2 >= H columns recovered from Hermitian symmetry:
      V(k1,k2)            = conj(M(k1, N2-k2))
      V((N1-k1)%N1, k2)   = conj(V(k1, N2-k2))
    where M = V[(N1-k1)%N1, :].
    """
    h = vre.shape[1]
    # M(k1,k2) = V((N1-k1)%N1, k2): reverse rows then roll by one.
    mre = jnp.roll(jnp.flip(vre, axis=0), 1, axis=0)
    mim = jnp.roll(jnp.flip(vim, axis=0), 1, axis=0)

    br, bi = crow_r[:h], crow_i[:h]
    # left half (k2 = 0..H-1):
    #   inner = b V + conj(b) conj(M)
    ir = br * vre - bi * vim + (br * mre - bi * mim)
    ii = br * vim + bi * vre - (br * mim + bi * mre)
    left = 2.0 * (ccol_r * ir - ccol_i * ii)

    # right half (k2 = H..N2-1, mapped to k2p = N2-k2 = 1..N2-H):
    #   inner = b(k2) conj(M(:,k2p)) + conj(b(k2)) V(:,k2p)
    w = n2 - h  # number of right-half columns
    if w > 0:
        rre = jnp.flip(vre[:, 1 : w + 1], axis=1)
        rim = jnp.flip(vim[:, 1 : w + 1], axis=1)
        rmre = jnp.flip(mre[:, 1 : w + 1], axis=1)
        rmim = jnp.flip(mim[:, 1 : w + 1], axis=1)
        br2, bi2 = crow_r[h:], crow_i[h:]
        #   b * conj(M)   = (br2 + j bi2)(rmre - j rmim)
        #                 = (br2*rmre + bi2*rmim) + j(bi2*rmre - br2*rmim)
        #   conj(b) * V   = (br2 - j bi2)(rre + j rim)
        #                 = (br2*rre + bi2*rim) + j(br2*rim - bi2*rre)
        jr = (br2 * rmre + bi2 * rmim) + (br2 * rre + bi2 * rim)
        ji = (bi2 * rmre - br2 * rmim) + (br2 * rim - bi2 * rre)
        right = 2.0 * (ccol_r * jr - ccol_i * ji)
        return jnp.concatenate([left, right], axis=1)
    return left


def dct2d_postprocess_jnp(vre, vim, n2: int):
    """Corrected Eq. (14) postprocess, plain-jnp implementation."""
    n1 = vre.shape[0]
    ar, ai = twiddle(n1, vre.dtype)
    br, bi = twiddle(n2, vre.dtype)
    return _post2d_math(vre, vim, n2, ar[:, None], ai[:, None], br, bi)


def _post2d_kernel(vre_ref, vim_ref, ar_ref, ai_ref, br_ref, bi_ref, o_ref, *, n2):
    o_ref[...] = _post2d_math(
        vre_ref[...],
        vim_ref[...],
        n2,
        ar_ref[...][:, None],
        ai_ref[...][:, None],
        br_ref[...],
        bi_ref[...],
    )


def dct2d_postprocess_pallas(vre, vim, n2: int):
    """Pallas version of the Eq. (14) postprocess.

    Twiddles enter as kernel operands (the paper parks them in texture
    cache; the TPU analogue is a VMEM-resident constant tile). Arithmetic
    intensity matches Table III's "our method" row: 2 complex reads ->
    4 real outputs with 16 mults + 12 adds per 4-tuple.
    """
    n1 = vre.shape[0]
    ar, ai = twiddle(n1, vre.dtype)
    br, bi = twiddle(n2, vre.dtype)
    return pl.pallas_call(
        partial(_post2d_kernel, n2=n2),
        out_shape=jax.ShapeDtypeStruct((n1, n2), vre.dtype),
        interpret=True,
    )(vre, vim, ar, ai, br, bi)
