"""L1 kernels for IDXST and the fused DREAMPlace transforms (paper §V-B).

DREAMPlace (Eq. 21) defines
    IDXST({x_n})_k = (-1)^k IDCT({x_{N-n}})_k,   x_N := 0,
and the 2D combinations (Eq. 22)
    IDCT_IDXST(x) = IDCT(IDXST(x)^T)^T  (1D IDCT along rows,
                                         then 1D IDXST along columns)
    IDXST_IDCT(x) = IDXST(IDCT(x)^T)^T.

Because the reverse-shift S and the (-1)^k sign flip are linear maps that
commute with the transform along the *other* axis, both combinations fold
into the SAME fused three-stage 2D IDCT (validated numerically, DESIGN.md):

    IDCT_IDXST(x) = diag((-1)^{k1}) . IDCT2D(S_rows x)
    IDXST_IDCT(x) = IDCT2D(S_cols x) . diag((-1)^{k2})

so the paradigm covers them with an O(N^2) fold into pre/postprocessing,
which is exactly the paper's claim of "stable performance regardless of
transform types".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import pallas_wrap

__all__ = [
    "shift_rows", "shift_cols",
    "sign_rows", "sign_cols",
    "shift_last",
    "sign_last",
    "shift_rows_pallas", "sign_rows_pallas",
]


def shift_rows(x):
    """S_rows: out[0,:] = 0, out[k,:] = x[N1-k,:] (zero reverse-shift)."""
    return jnp.concatenate(
        [jnp.zeros_like(x[:1, :]), jnp.flip(x[1:, :], axis=0)], axis=0
    )


def shift_cols(x):
    """S_cols: out[:,0] = 0, out[:,k] = x[:,N2-k]."""
    return jnp.concatenate(
        [jnp.zeros_like(x[:, :1]), jnp.flip(x[:, 1:], axis=1)], axis=1
    )


def shift_last(x):
    """S along the last axis for arbitrary-rank input (1D baseline path)."""
    return jnp.concatenate(
        [jnp.zeros_like(x[..., :1]), jnp.flip(x[..., 1:], axis=-1)], axis=-1
    )


def _signs(n, dtype):
    return jnp.asarray((-1.0) ** np.arange(n), dtype=dtype)


def sign_rows(x):
    """diag((-1)^{k1}) . x"""
    return x * _signs(x.shape[0], x.dtype)[:, None]


def sign_cols(x):
    """x . diag((-1)^{k2})"""
    return x * _signs(x.shape[1], x.dtype)[None, :]


def sign_last(x):
    """(-1)^k scaling along the last axis."""
    return x * _signs(x.shape[-1], x.dtype)


def shift_rows_pallas(x):
    """Pallas form of S_rows (fused into the IDCT preprocess on TPU)."""
    return pallas_wrap(shift_rows, jax.ShapeDtypeStruct(x.shape, x.dtype), x)


def sign_rows_pallas(x):
    """Pallas form of the (-1)^{k1} postprocess fold.

    The sign vector is an explicit kernel operand (Pallas kernels may not
    capture array constants), mirroring the precomputed-coefficient
    convention used for twiddles.
    """
    s = _signs(x.shape[0], x.dtype)
    return pallas_wrap(
        lambda xv, sv: xv * sv[:, None],
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        x, s,
    )
