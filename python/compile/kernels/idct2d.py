"""L1 kernels for the fused 2D IDCT (paper Algorithm 2, lines 5-8).

Three-stage decomposition (mirror image of the forward transform):
  preprocess  : build the onesided Hermitian spectrum from the real input
                (Eq. 15, with the conjugated twiddles and global 1/4 the
                printed formula is missing -- see DESIGN.md)
  2D IRFFT    : performed by the L2 pipeline (jnp.fft.irfft2)
  postprocess : inverse butterfly reorder (Eq. 16)

The preprocess reads four mirrored input elements per spectrum entry and
writes each onesided entry exactly once, matching the paper's "each thread
reads four elements from the input matrix and writes two elements [one
complex] to the output" description of the 2D IDCT preprocessing.
"""
from __future__ import annotations

import jax.numpy as jnp

from .common import pallas_wrap, twiddle, unreorder_2d

__all__ = [
    "idct2d_preprocess_jnp",
    "idct2d_preprocess_pallas",
    "idct2d_postprocess_jnp",
    "idct2d_postprocess_pallas",
]


def _zflip_rows(x):
    """Zero-boundary row flip: out[0]=0, out[k]=x[N1-k]."""
    return jnp.concatenate(
        [jnp.zeros_like(x[:1, :]), jnp.flip(x[1:, :], axis=0)], axis=0
    )


def _pre_math(x, ar, ai, br, bi, h):
    """V[:, :H] = (conj(a) conj(b) / 4) * (x - f12 - j (f1 + f2)).

    ar/ai: twiddle a(k1)=e^{-j pi k1/2N1} as (N1, 1) columns;
    br/bi: twiddle b(k2) restricted to the H onesided columns.
    Returns (Vre, Vim) of shape (N1, H).
    """
    n1, n2 = x.shape
    xl = x[:, :h]
    # f2 on the onesided columns: out[:,0]=0, out[:,k2]=x[:,N2-k2]
    f2 = jnp.concatenate(
        [jnp.zeros_like(x[:, :1]), jnp.flip(x[:, n2 - h + 1 :], axis=1)], axis=1
    )
    f1 = _zflip_rows(xl)
    f12 = _zflip_rows(f2)
    p = xl - f12
    q = f1 + f2
    # c = conj(a)*conj(b) = (ar*br - ai*bi) - j(ar*bi + ai*br)
    cr = ar * br - ai * bi
    ci = -(ar * bi + ai * br)
    # V = c/4 * (p - j q)
    vre = 0.25 * (cr * p + ci * q)
    vim = 0.25 * (ci * p - cr * q)
    return vre, vim


def idct2d_preprocess_jnp(x):
    """Eq. (15) (corrected) on the onesided columns, plain jnp."""
    n1, n2 = x.shape
    h = n2 // 2 + 1
    ar, ai = twiddle(n1, x.dtype)
    br, bi = twiddle(n2, x.dtype)
    return _pre_math(x, ar[:, None], ai[:, None], br[:h], bi[:h], h)


def idct2d_preprocess_pallas(x):
    """Pallas version of the corrected Eq. (15) preprocess."""
    import jax

    n1, n2 = x.shape
    h = n2 // 2 + 1
    ar, ai = twiddle(n1, x.dtype)
    br, bi = twiddle(n2, x.dtype)
    out = jax.ShapeDtypeStruct((n1, h), x.dtype)
    return pallas_wrap(
        lambda xv, arv, aiv, brv, biv: _pre_math(
            xv, arv[:, None], aiv[:, None], brv, biv, h
        ),
        (out, out),
        x, ar, ai, br[:h], bi[:h],
    )


def idct2d_postprocess_jnp(v):
    """Eq. (16): inverse butterfly reorder of the IRFFT output."""
    return unreorder_2d(v)


def idct2d_postprocess_pallas(v):
    """Pallas version of the Eq. (16) reorder."""
    import jax

    return pallas_wrap(
        unreorder_2d, jax.ShapeDtypeStruct(v.shape, v.dtype), v
    )
