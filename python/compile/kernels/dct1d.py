"""L1 kernels for the four 1D DCT-via-FFT algorithms (paper Algorithm 1).

  4N        : zero-interleaved length-4N sequence, postprocess = Re(X[:N])
  mirrored2N: [x, flip(x)],  postprocess =   Re(e^{-j pi k/2N} X[:N])
  padded 2N : [x, zeros(N)], postprocess = 2 Re(e^{-j pi k/2N} X[:N])
  N         : butterfly reorder, postprocess via Eq. (11) on the onesided
              spectrum (the algorithm the paper focuses on)

plus the inverse (IDCT) three-stage form used by the row-column baseline.

All preprocess/postprocess functions operate on the LAST axis and accept
batched (matrix) inputs, which is what the row-column 2D baseline feeds
them. The RFFT itself lives in the L2 pipeline (model.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import pallas_wrap, reorder_1d, twiddle, unreorder_1d

__all__ = [
    "dct_4n_preprocess", "dct_4n_postprocess",
    "dct_2n_mirror_preprocess", "dct_2n_mirror_postprocess",
    "dct_2n_pad_preprocess", "dct_2n_pad_postprocess",
    "dct_n_preprocess", "dct_n_postprocess",
    "idct_n_preprocess", "idct_n_postprocess",
    "dct_n_preprocess_pallas", "dct_n_postprocess_pallas",
]


# ---------------------------------------------------------------- 4N ----

def dct_4n_preprocess(x):
    """Eq. (3): zero-interleave x into a length-4N sequence."""
    n = x.shape[-1]
    z = jnp.zeros(x.shape[:-1] + (4 * n,), x.dtype)
    z = z.at[..., 1 : 2 * n : 2].set(x)
    z = z.at[..., 2 * n + 1 :: 2].set(jnp.flip(x, axis=-1))
    return z


def dct_4n_postprocess(vre, vim, n: int):
    """Eq. (4): y = Re(X[:N]). Onesided length 2N+1 >= N, so direct."""
    del vim
    return vre[..., :n]


# ------------------------------------------------------- mirrored 2N ----

def dct_2n_mirror_preprocess(x):
    """Eq. (5): mirror-extend x to length 2N."""
    return jnp.concatenate([x, jnp.flip(x, axis=-1)], axis=-1)


def dct_2n_mirror_postprocess(vre, vim, n: int):
    """Eq. (6): y = Re(e^{-j pi k / 2N} X(k)), onesided length N+1 >= N."""
    cr, ci = twiddle(n, vre.dtype)
    return cr * vre[..., :n] - ci * vim[..., :n]


# --------------------------------------------------------- padded 2N ----

def dct_2n_pad_preprocess(x):
    """Eq. (7): zero-pad x to length 2N."""
    return jnp.concatenate([x, jnp.zeros_like(x)], axis=-1)


def dct_2n_pad_postprocess(vre, vim, n: int):
    """Eq. (8): y = 2 Re(e^{-j pi k / 2N} X(k))."""
    cr, ci = twiddle(n, vre.dtype)
    return 2.0 * (cr * vre[..., :n] - ci * vim[..., :n])


# ------------------------------------------------------------------ N ----

def dct_n_preprocess(x):
    """Eq. (9): even/odd butterfly reorder (length stays N)."""
    return reorder_1d(x)


def dct_n_postprocess(vre, vim, n: int):
    """Eq. (11): twiddle the onesided spectrum, Hermitian right half.

    Onesided H = N//2 + 1. For k < H:  y = 2 Re(e^{-j t k} X(k));
    for k >= H: X(k) = conj(X(N-k)) with N-k in [1, N-H].
    """
    h = vre.shape[-1]
    cr, ci = twiddle(n, vre.dtype)
    left = 2.0 * (cr[:h] * vre - ci[:h] * vim)
    w = n - h
    if w == 0:
        return left
    rre = jnp.flip(vre[..., 1 : w + 1], axis=-1)
    rim = -jnp.flip(vim[..., 1 : w + 1], axis=-1)  # conjugate
    right = 2.0 * (cr[h:] * rre - ci[h:] * rim)
    return jnp.concatenate([left, right], axis=-1)


def dct_n_preprocess_pallas(x):
    """Pallas form of the Eq. (9) reorder (whole-row VMEM tile)."""
    return pallas_wrap(
        reorder_1d, jax.ShapeDtypeStruct(x.shape, x.dtype), x
    )


def dct_n_postprocess_pallas(vre, vim, n: int):
    """Pallas form of the Eq. (11) postprocess.

    The twiddle tables are explicit kernel operands (precomputed-per-plan,
    like the paper's texture-cache coefficients).
    """
    h = vre.shape[-1]
    cr, ci = twiddle(n, vre.dtype)
    out = jax.ShapeDtypeStruct(vre.shape[:-1] + (n,), vre.dtype)

    def body(a, b, crv, civ):
        left = 2.0 * (crv[:h] * a - civ[:h] * b)
        w = n - h
        if w == 0:
            return left
        rre = jnp.flip(a[..., 1 : w + 1], axis=-1)
        rim = -jnp.flip(b[..., 1 : w + 1], axis=-1)
        right = 2.0 * (crv[h:] * rre - civ[h:] * rim)
        return jnp.concatenate([left, right], axis=-1)

    return pallas_wrap(body, out, vre, vim, cr, ci)


# ------------------------------------------------------------- IDCT ----

def idct_n_preprocess(x):
    """Inverse N-point preprocess: build the onesided spectrum.

    V(k) = conj(a(k))/2 * (x(k) - j x~(k)), x~ the zero-boundary reverse
    (x~(0)=0, x~(k)=x(N-k)), evaluated at the H = N//2+1 onesided bins.
    This is the 1D restriction of the corrected Eq. (15).
    """
    n = x.shape[-1]
    h = n // 2 + 1
    cr, ci = twiddle(n, x.dtype)
    xl = x[..., :h]
    xt = jnp.concatenate(
        [jnp.zeros_like(x[..., :1]), jnp.flip(x[..., n - h + 1 :], axis=-1)],
        axis=-1,
    )
    # conj(a) = cr - j ci ; V = conj(a)/2 (xl - j xt)
    vre = 0.5 * (cr[:h] * xl - ci[:h] * xt)
    vim = 0.5 * (-ci[:h] * xl - cr[:h] * xt)
    return vre, vim


def idct_n_postprocess(v):
    """Inverse N-point postprocess: undo the butterfly reorder."""
    return unreorder_1d(v)
