"""Shared helpers for the L1 transform kernels.

All kernels in this package follow the paper's three-stage decomposition
(preprocess -> RFFT -> postprocess). The helpers here compute twiddle
factors and butterfly reorderings shared by the 1D and 2D kernels.

Complex values are carried as (re, im) float pairs so the Pallas kernels
never touch a complex dtype (mirrors the paper's CUDA kernels, which also
operate on interleaved scalar floats).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "twiddle",
    "reorder_1d",
    "unreorder_1d",
    "reorder_2d",
    "unreorder_2d",
    "cmul",
    "cconj",
    "pallas_wrap",
]


def pallas_wrap(fn, out_shapes, *args):
    """Run `fn(*arrays) -> array or tuple` as a Pallas kernel (interpret).

    This is the uniform adapter that turns the vectorized kernel math into
    a `pl.pallas_call` with whole-array blocks: every operand is one VMEM
    tile. On a real TPU the same bodies would be tiled by BlockSpec; on the
    CPU PJRT plugin only interpret mode is executable (Mosaic custom-calls
    are TPU-only), so interpret=True is mandatory here.
    """
    import jax
    from jax.experimental import pallas as pl

    single = not isinstance(out_shapes, (list, tuple))
    shapes = [out_shapes] if single else list(out_shapes)

    def kernel(*refs):
        in_refs = refs[: len(args)]
        out_refs = refs[len(args):]
        res = fn(*[r[...] for r in in_refs])
        if single:
            res = (res,)
        for o_ref, r in zip(out_refs, res):
            o_ref[...] = r

    out = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(s.shape, s.dtype) for s in shapes],
        interpret=True,
    )(*args)
    return out[0] if single else tuple(out)


def twiddle(n: int, dtype=jnp.float32):
    """Return (cos, sin) of the postprocessing twiddle e^{-j pi k / 2n}.

    The paper precomputes this table once per plan ("the terms of a and b
    ... are pre-computed and fixed before the call of the DCT procedures").
    We bake it into the HLO as a constant, which XLA materializes once.
    """
    k = np.arange(n)
    theta = -np.pi * k / (2.0 * n)
    return (
        jnp.asarray(np.cos(theta), dtype=dtype),
        jnp.asarray(np.sin(theta), dtype=dtype),
    )


def cmul(ar, ai, br, bi):
    """Complex multiply on (re, im) pairs: (ar + j ai) * (br + j bi)."""
    return ar * br - ai * bi, ar * bi + ai * br


def cconj(ar, ai):
    """Complex conjugate on (re, im) pairs."""
    return ar, -ai


def reorder_1d(x):
    """Butterfly (even/odd) reorder of the last axis, Eq. (9) of the paper.

    v[n] = x[2n]            for 0 <= n <= floor((N-1)/2)
    v[n] = x[2N - 2n - 1]   for floor((N+1)/2) <= n < N
    which is exactly `concat(x[0::2], flip(x[1::2]))`.
    """
    return jnp.concatenate(
        [x[..., 0::2], jnp.flip(x[..., 1::2], axis=-1)], axis=-1
    )


def unreorder_1d(x):
    """Inverse of :func:`reorder_1d` (Eq. (16) restricted to one axis)."""
    n = x.shape[-1]
    half = (n + 1) // 2
    out = jnp.zeros_like(x)
    out = out.at[..., 0::2].set(x[..., :half])
    out = out.at[..., 1::2].set(jnp.flip(x[..., half:], axis=-1))
    return out


def reorder_2d(x):
    """2D butterfly reorder, Eq. (13): the 1D reorder applied to both axes.

    The paper performs this in a single fused pass ("we perform the
    reordering in one step for the 2D input"); composing the two jnp
    reorders fuses into one gather in XLA as well.
    """
    v = jnp.concatenate([x[0::2, :], jnp.flip(x[1::2, :], axis=0)], axis=0)
    return jnp.concatenate([v[:, 0::2], jnp.flip(v[:, 1::2], axis=1)], axis=1)


def unreorder_2d(x):
    """Inverse of :func:`reorder_2d`, Eq. (16)."""
    n1, n2 = x.shape
    h1, h2 = (n1 + 1) // 2, (n2 + 1) // 2
    y = jnp.zeros_like(x)
    y = y.at[0::2, :].set(x[:h1, :])
    y = y.at[1::2, :].set(jnp.flip(x[h1:, :], axis=0))
    z = jnp.zeros_like(x)
    z = z.at[:, 0::2].set(y[:, :h2])
    z = z.at[:, 1::2].set(jnp.flip(y[:, h2:], axis=1))
    return z
