"""AOT-lower every L2 pipeline to HLO text + a manifest for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and gen_hlo.py.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Usage:  python -m compile.aot --outdir ../artifacts [--filter dct2d]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPE = jnp.float32
DTYPE_NAME = "f32"


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPE)


def manifest_entries():
    """The artifact manifest: (name, pipeline, [input shapes]).

    Sizes are chosen so XLA-CPU compile times stay in seconds; the Rust
    native backend sweeps the paper's full 8192^2 range. Rectangular
    shapes cover Table V's 100x10000 aspect-ratio observation (scaled).
    """
    entries = []
    sq = [64, 128, 256, 512]
    rect = [(32, 1024), (1024, 32)]

    for n in sq:
        entries.append((f"dct2d_{n}x{n}", "dct2d", [(n, n)]))
        entries.append((f"idct2d_{n}x{n}", "idct2d", [(n, n)]))
        entries.append((f"rc_dct2d_{n}x{n}", "rc_dct2d", [(n, n)]))
        entries.append((f"rc_idct2d_{n}x{n}", "rc_idct2d", [(n, n)]))
        entries.append((f"rfft2d_{n}x{n}", "rfft2d", [(n, n)]))
    for n1, n2 in rect:
        entries.append((f"dct2d_{n1}x{n2}", "dct2d", [(n1, n2)]))
        entries.append((f"rc_dct2d_{n1}x{n2}", "rc_dct2d", [(n1, n2)]))
        entries.append((f"rfft2d_{n1}x{n2}", "rfft2d", [(n1, n2)]))
    # MATLAB stand-in baseline (order-of-magnitude-slower library method)
    for n in [64, 128, 256, 512]:
        entries.append((f"matmul_dct2d_{n}x{n}", "matmul_dct2d", [(n, n)]))
    # Proof of the Pallas L1 -> HLO -> PJRT path
    entries.append(("dct2d_pallas_128x128", "dct2d_pallas", [(128, 128)]))
    entries.append(("idct2d_pallas_128x128", "idct2d_pallas", [(128, 128)]))
    # 1D: four algorithms (Table IV)
    for n in [1024, 4096, 16384]:
        for algo in ["dct1d_4n", "dct1d_2n_mirror", "dct1d_2n_pad", "dct1d_n"]:
            entries.append((f"{algo}_{n}", algo, [(n,)]))
    entries.append(("idct1d_4096", "idct1d", [(4096,)]))
    # DREAMPlace transforms (§V-B)
    for n in [256, 512]:
        entries.append((f"idct_idxst_{n}x{n}", "idct_idxst", [(n, n)]))
        entries.append((f"idxst_idct_{n}x{n}", "idxst_idct", [(n, n)]))
        entries.append((f"rc_idct_idxst_{n}x{n}", "rc_idct_idxst", [(n, n)]))
        entries.append((f"rc_idxst_idct_{n}x{n}", "rc_idxst_idct", [(n, n)]))
    # DST family (§III-D extensibility)
    entries.append(("dst2d_256x256", "dst2d", [(256, 256)]))
    entries.append(("idst2d_256x256", "idst2d", [(256, 256)]))
    # Application pipelines
    entries.append(("image_compress_256x256", "image_compress", [(256, 256), ()]))
    entries.append(("placement_force_256x256", "placement_force", [(256, 256)]))
    entries.append(("placement_force_512x512", "placement_force", [(512, 512)]))
    return entries


def to_hlo_text(fn, in_specs) -> str:
    """Lower a jitted function to XLA HLO text via StableHLO.

    `print_large_constants=True` is REQUIRED: the default HLO printer
    elides big literals as `constant({...})`, which the XLA text parser
    silently turns into zero-filled constants — the twiddle tables and
    cosine matrices would vanish from the artifact.
    """
    lowered = jax.jit(fn).lower(*[_spec(s) for s in in_specs])
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def out_specs(fn, in_specs):
    """Output shapes/dtypes via abstract evaluation (no compute)."""
    res = jax.eval_shape(fn, *[_spec(s) for s in in_specs])
    leaves = jax.tree_util.tree_leaves(res)
    return [{"shape": list(l.shape), "dtype": DTYPE_NAME} for l in leaves]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--filter", default=None,
                    help="only emit artifacts whose name contains this substring")
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy
    args = ap.parse_args()

    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)

    manifest = {"version": 1, "dtype": DTYPE_NAME, "entries": []}
    t0 = time.time()
    entries = manifest_entries()
    if args.filter:
        entries = [e for e in entries if args.filter in e[0]]
    for name, pipeline, in_shapes in entries:
        fn = model.PIPELINES[pipeline]
        text = to_hlo_text(fn, in_shapes)
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name,
            "pipeline": pipeline,
            "file": fname,
            "inputs": [{"shape": list(s), "dtype": DTYPE_NAME} for s in in_shapes],
            "outputs": out_specs(fn, in_shapes),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  [{time.time()-t0:6.1f}s] {name}: {len(text)} chars")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} artifacts + manifest.json "
          f"to {outdir} in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
