//! Whole-image DCT compression (paper §V-A, Algorithm 3).
//!
//! Sweeps the magnitude threshold eps on a synthetic photographic image
//! and reports sparsity vs PSNR, then times the fused pipeline against
//! the row-column implementation of the same pipeline (the application-
//! level view of the paper's 2x claim: here p = 1 in Amdahl's law, so
//! the app inherits the full transform speedup).
//!
//! Run: `cargo run --release --example image_compression`

use mddct::apps::{psnr, synthetic_image, Compressor};
use mddct::bench::{time_fn, BenchConfig};
use mddct::dct::RowColumn;

fn main() {
    let n = 512;
    let img = synthetic_image(n, n, 3);
    let compressor = Compressor::new(n, n);

    println!("image {n}x{n}, threshold sweep (Algorithm 3 / Eq. 20):");
    println!("{:>10} {:>12} {:>10}", "eps", "sparsity", "PSNR dB");
    for eps in [0.0, 10.0, 50.0, 200.0, 1000.0, 5000.0] {
        let rep = compressor.report(&img, eps);
        println!("{:>10.1} {:>11.1}% {:>10.2}", eps, rep.sparsity * 100.0, rep.psnr_db);
    }

    // fused vs row-column end-to-end compression timing
    let cfg = BenchConfig::from_env(BenchConfig::default());
    let fused = time_fn(&cfg, || {
        let (rec, _) = compressor.compress(&img, 50.0);
        std::hint::black_box(rec);
    });

    let rc_dct = RowColumn::dct2(n, n);
    let rc_idct = RowColumn::idct2(n, n);
    let rowcol = time_fn(&cfg, || {
        let mut spec = vec![0.0; n * n];
        rc_dct.forward(&img, &mut spec);
        for v in spec.iter_mut() {
            if v.abs() < 50.0 {
                *v = 0.0;
            }
        }
        let mut out = vec![0.0; n * n];
        rc_idct.forward(&spec, &mut out);
        std::hint::black_box(out);
    });
    println!(
        "\npipeline time: fused {:.2} ms vs row-column {:.2} ms  ({:.2}x)",
        fused.mean * 1e3,
        rowcol.mean * 1e3,
        rowcol.mean / fused.mean
    );

    // sanity: both pipelines reconstruct the same image
    let (a, _) = compressor.compress(&img, 50.0);
    let mut spec = vec![0.0; n * n];
    rc_dct.forward(&img, &mut spec);
    for v in spec.iter_mut() {
        if v.abs() < 50.0 {
            *v = 0.0;
        }
    }
    let mut b = vec![0.0; n * n];
    rc_idct.forward(&spec, &mut b);
    println!(
        "fused-vs-rowcol reconstruction PSNR: {:.1} dB (identical => inf)",
        psnr(&a, &b, 255.0)
    );
}
