//! End-to-end driver: DREAMPlace-style electrostatic placement
//! (paper §V-B, Algorithm 4) on a synthetic ISPD-scale circuit.
//!
//! Runs the full loop — density map -> spectral potential+force
//! (DCT2D / IDCT_IDXST / IDXST_IDCT) -> cell movement — for dozens of
//! iterations, logging the density-overflow curve (the placement
//! analogue of a training-loss curve), and A/Bs the fused transforms
//! against the row-column baseline with identical physics.
//!
//! Run: `cargo run --release --example placement`

use mddct::apps::{IspdBenchmark, PlacementEngine, SolverBackend};

fn main() {
    // laptop-scale instance: 50k cells on a 256^2 grid (adaptec-shaped)
    let bench = IspdBenchmark { name: "adaptec1-s", cells: 50_000, grid: 256 };
    let iters = 24;

    for backend in [SolverBackend::Fused, SolverBackend::RowColumn] {
        let mut circuit = bench.generate(1);
        let engine = PlacementEngine::new(bench.grid, backend);
        let label = match backend {
            SolverBackend::Fused => "fused (ours)",
            SolverBackend::RowColumn => "row-column",
        };
        println!(
            "\n== {} | {} cells, {}x{} grid, {iters} iterations ==",
            label,
            circuit.cells(),
            bench.grid,
            bench.grid
        );
        let t0 = std::time::Instant::now();
        let reports = engine.run(&mut circuit, iters);
        let total = t0.elapsed().as_secs_f64();
        let transform: f64 = reports.iter().map(|r| r.transform_seconds).sum();
        let other: f64 = reports.iter().map(|r| r.other_seconds).sum();
        for r in reports.iter().step_by(4) {
            println!(
                "  iter {:>2}: overflow {:.4e}  (transform {:.2} ms, other {:.2} ms)",
                r.iter,
                r.overflow,
                r.transform_seconds * 1e3,
                r.other_seconds * 1e3
            );
        }
        let first = reports.first().unwrap().overflow;
        let last = reports.last().unwrap().overflow;
        println!(
            "  total {total:.2}s = transform {transform:.2}s + other {other:.2}s \
             (p = {:.2} in Amdahl terms)",
            transform / total
        );
        println!(
            "  overflow {first:.4e} -> {last:.4e}  ({:.1}% reduction)",
            (1.0 - last / first) * 100.0
        );
        assert!(last < first, "spreading must reduce overlap");
    }
}
