//! End-to-end driver: the transform service under a batched request load
//! (the serving view of the paper: stable, FFT-comparable latency across
//! transform types, multi-worker scaling — §III-D's multi-device
//! discussion mapped to a worker pool).
//!
//! Submits a mixed workload of 2D DCT / IDCT / IDCT_IDXST requests of
//! several shapes from multiple client threads, reports throughput,
//! latency percentiles, batch statistics, and worker-count scaling.
//!
//! Run: `cargo run --release --example serve` (add `--pjrt` after `--`
//! to route shapes with AOT artifacts to the PJRT backend)

use std::sync::Arc;

use mddct::cli::Args;
use mddct::coordinator::{
    BatchPolicy, Router, Service, ServiceConfig, TransformOp,
};
use mddct::runtime::{Manifest, PjrtHandle, DEFAULT_ARTIFACT_DIR};
use mddct::util::rng::Rng;

fn make_router(use_pjrt: bool) -> Router {
    if use_pjrt {
        if let Ok(m) = Manifest::load(DEFAULT_ARTIFACT_DIR) {
            println!("routing to PJRT artifacts where shapes match");
            return Router::with_pjrt(PjrtHandle::spawn(DEFAULT_ARTIFACT_DIR), &m);
        }
        println!("artifacts missing; native backend only");
    }
    Router::native_only()
}

fn run_load(workers: usize, use_pjrt: bool, requests: usize) -> (f64, f64, f64) {
    let svc = Arc::new(Service::start(
        ServiceConfig { workers, batch: BatchPolicy::default(), ..Default::default() },
        make_router(use_pjrt),
    ));
    let clients = 4;
    let per_client = requests / clients;
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            let mut lat = Vec::new();
            for i in 0..per_client {
                let (op, n) = match (i + c) % 4 {
                    0 => (TransformOp::Dct2d, 256),
                    1 => (TransformOp::Idct2d, 256),
                    2 => (TransformOp::Dct2d, 128),
                    _ => (TransformOp::IdctIdxst, 256),
                };
                let data = rng.normal_vec(n * n);
                let r = svc.transform(op, vec![n, n], data).expect("transform");
                lat.push(r.latency);
            }
            lat
        }));
    }
    let mut latencies: Vec<f64> = Vec::new();
    for j in joins {
        latencies.extend(j.join().unwrap());
    }
    let dt = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p95 = latencies[latencies.len() * 95 / 100];
    (latencies.len() as f64 / dt, p50, p95)
}

fn main() {
    let args = Args::from_env();
    let use_pjrt = args.flag_bool("pjrt");
    let requests = args.flag_usize("requests", 256);

    println!("mixed workload: dct2d/idct2d/idct_idxst over 128^2 & 256^2, {requests} requests");
    println!("{:>8} {:>12} {:>10} {:>10}", "workers", "req/s", "p50 ms", "p95 ms");
    let mut last = 0.0;
    for workers in [1, 2, 4, 8] {
        let (rps, p50, p95) = run_load(workers, use_pjrt, requests);
        println!(
            "{workers:>8} {rps:>12.1} {:>10.2} {:>10.2}",
            p50 * 1e3,
            p95 * 1e3
        );
        last = rps;
    }
    assert!(last > 0.0);
}
