//! Quickstart: the four ways to run a fused 2D DCT with mddct.
//!
//!   1. direct plan API       (lowest overhead, single transform)
//!   2. transform service     (batching + plan cache, production path)
//!   3. band-sharded plan     (one large transform split across the pool)
//!   4. PJRT artifact         (the JAX/Pallas AOT kernel, if built)
//!
//! Run: `cargo run --release --example quickstart`

use mddct::coordinator::{Service, ServiceConfig, TransformOp};
use mddct::dct::{Dct2, Idct2};
use mddct::parallel::{default_threads, ExecPolicy, ShardPolicy};
use mddct::runtime::{Manifest, PjrtHandle, DEFAULT_ARTIFACT_DIR};
use mddct::util::rng::Rng;

fn main() {
    let n = 256;
    let mut rng = Rng::new(1);
    let x = rng.normal_vec(n * n);

    // --- 1. direct plan API -------------------------------------------
    let dct = Dct2::new(n, n);
    let mut y = vec![0.0; n * n];
    let times = dct.forward_timed(&x, &mut y);
    println!(
        "[plan]    dct2d {n}x{n}: {:.3} ms (pre {:.3} + fft {:.3} + post {:.3})",
        times.total() * 1e3,
        times.pre * 1e3,
        times.fft * 1e3,
        times.post * 1e3
    );

    // verify invertibility
    let idct = Idct2::new(n, n);
    let mut back = vec![0.0; n * n];
    idct.forward(&y, &mut back);
    let err = x
        .iter()
        .zip(&back)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("[plan]    roundtrip max error: {err:.2e}");
    assert!(err < 1e-9);

    // --- 2. transform service ------------------------------------------
    let svc = Service::start_native(ServiceConfig::default());
    let resp = svc
        .transform(TransformOp::Dct2d, vec![n, n], x.clone())
        .expect("service transform");
    let diff = resp
        .output
        .iter()
        .zip(&y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "[service] dct2d via {} backend, latency {:.3} ms, matches plan path: {}",
        resp.backend,
        resp.latency * 1e3,
        diff < 1e-9
    );

    // --- 3. band-sharded large transform -------------------------------
    let big = 1024;
    let xb = rng.normal_vec(big * big);
    let mut yb = vec![0.0; big * big];
    let single = Dct2::with_policy(big, big, ExecPolicy::Serial)
        .with_shards(ShardPolicy::MaxShards(1));
    let t0 = std::time::Instant::now();
    single.forward(&xb, &mut yb);
    let t_one = t0.elapsed().as_secs_f64();
    let shards = default_threads().max(2);
    let banded = Dct2::with_policy(big, big, ExecPolicy::Serial)
        .with_shards(ShardPolicy::MaxShards(shards));
    let mut yb2 = vec![0.0; big * big];
    let t0 = std::time::Instant::now();
    banded.forward(&xb, &mut yb2);
    let t_many = t0.elapsed().as_secs_f64();
    let sd = yb
        .iter()
        .zip(&yb2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "[shard]   dct2d {big}x{big}: 1 shard {:.1} ms vs {shards} shards {:.1} ms \
         ({:.2}x), max diff {sd:.1e}",
        t_one * 1e3,
        t_many * 1e3,
        t_one / t_many
    );
    // the sharding contract: <= 1e-10 relative to the output scale
    let scale = yb.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    assert!(sd <= 1e-10 * scale);

    // --- 4. PJRT artifact (optional) -----------------------------------
    match Manifest::load(DEFAULT_ARTIFACT_DIR) {
        Ok(m) if m.entries.contains_key("dct2d_256x256") => {
            let handle = PjrtHandle::spawn(DEFAULT_ARTIFACT_DIR);
            let t0 = std::time::Instant::now();
            let out = handle
                .run("dct2d_256x256", vec![x.clone()])
                .expect("pjrt run");
            let dt = t0.elapsed().as_secs_f64();
            let scale = y.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            let maxrel = out[0]
                .iter()
                .zip(&y)
                .map(|(a, b)| (a - b).abs() / scale)
                .fold(0.0f64, f64::max);
            println!(
                "[pjrt]    dct2d artifact (f32, first call incl. XLA compile): \
                 {:.1} ms, max rel err vs native f64: {maxrel:.2e}",
                dt * 1e3
            );
            assert!(maxrel < 1e-3);
        }
        _ => println!("[pjrt]    artifacts/ not built — run `make artifacts` first"),
    }
    println!("quickstart OK");
}
